"""Paged KV cache: fixed-size pages + slot indirection for decode.

The contiguous slot cache (PR 1) reserves ``slots x max_len`` worst-case
K/V per layer group.  This module pages it, vLLM/TensorRT-LLM style:

  * K/V storage is a *pool* of fixed-size pages per layer group, stored
    page-major and layout-canonical: ``[G, P, page_size, Hkv, hd]``
    regardless of the model's ``kv_cache_layout`` (append/gather adapt at
    the edges, so both "bshd" and "bhsd" configs run paged).
  * A device-resident page table ``[slots, max_pages] int32`` maps each
    slot's logical page j to a physical page id.  Physical page 0 is the
    NULL page: unallocated table entries point at it, so inactive slots'
    decode writes land in a sacrificial page and data-dependent page
    lookups (the Pallas kernel's scalar-prefetch index map) never read out
    of bounds.
  * Pages are allocated from a host-side free list as a slot's sequence
    grows and returned when the request finishes — bytes-in-use is
    ``pages_in_use * page_bytes``, not ``slots * max_len`` worst case.
  * Allocation is REFCOUNTED (DESIGN.md §10): several slots may reference
    the same physical page (a shared prompt prefix), and the prefix cache
    (``serving/prefix_cache.py``) may hold a page *cached* after every
    referencing slot exits.  A page is therefore in exactly one of three
    states — free (on the free list), referenced (``refs > 0``), or
    cached (tree-owned, ``refs == 0``, reclaimed lazily through the
    ``evictor`` hook when the free list runs dry) — and
    ``assert_page_accounting`` checks that partition.  Shared pages are
    never written in place: the first divergent write goes through a
    copy-on-write page swap (``cow_page`` + the ``cow_src``/``cow_dst``
    operands of ``paged_append``/``place_chunk_pages``).
  * Non-sequence state leaves (SSM / conv / wkv / token-shift) carry no
    sequence axis; they stay slot-contiguous ``[G, slots, ...]`` and are
    whole-replaced per slot.  Leaf classification comes from the shared
    schema in ``models/params.py`` (``cache_leaf_kind``) — an unknown leaf
    raises instead of being silently mishandled.

The functional primitives (``paged_append`` / ``gather_pages`` /
``place_prefill``) are pure: they take and return arrays so the engine can
run them inside donated jits, and ``models/model.py`` calls
``paged_append`` from the decode step when a page table is passed.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.params import (CacheDef, cache_defs, cache_leaf_kind,
                             cache_leaf_name)
from ..obs import (NULL_RECORDER, PAGE_ALLOC, PAGE_COW, PAGE_EVICT,
                   PAGE_FREE, PAGE_ROLLBACK, TRACK_KV)

Tree = Any

NULL_PAGE = 0       # physical page reserved as the write sink for
#                     unallocated table entries / inactive slots

# Paged-memory invariants the static analyzer (analysis/effects.py)
# checks the pool schema and dispatch effect signatures against — the
# declarative twin of the runtime ``assert_page_accounting`` audit.
POOL_INVARIANTS = {
    # Every page-table-indexed scatter masks dead rows onto NULL_PAGE;
    # page 0 is sacrificial and never allocated to a slot.
    "null_page": NULL_PAGE,
    # Under a KV QuantMode every value pool leaf ``<name>`` carries a
    # sibling ``<name>_scale`` [G, num_pages, Hkv] f32 indexed by the
    # SAME physical page ids; appends/COW/chunk placement update both in
    # lockstep (scales grow monotonically so codes stay valid).
    "scale_suffix": "_scale",
    "scale_dtype": "float32",
    # ``cow_page`` allocates the private dst page fresh (refs == 1,
    # never the src unless both are NULL) before any divergent write.
    "cow_fresh_dst": True,
}


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# --------------------------------------------------------------------- #
# Functional primitives (jit-safe, layout-adapting)
# --------------------------------------------------------------------- #

def to_page_major(seq: jax.Array, layout: str) -> jax.Array:
    """K/V with a batch axis -> canonical [..., S, H, hd] order.

    seq: [B, S, H, hd] ("bshd") or [B, H, S, hd] ("bhsd").
    """
    if layout == "bhsd":
        return jnp.swapaxes(seq, -3, -2)
    return seq


def from_page_major(seq: jax.Array, layout: str) -> jax.Array:
    """Inverse of ``to_page_major``."""
    if layout == "bhsd":
        return jnp.swapaxes(seq, -3, -2)
    return seq


# --------------------------------------------------------------------- #
# KV quantization (DESIGN.md §14)
# --------------------------------------------------------------------- #
#
# Quantized pools store CODES: ``value ≈ code * scale`` with one f32 scale
# per (physical page, kv head) riding in a scale pool ``[G, num_pages,
# Hkv]`` next to each value pool.  Scales are per-page so the paged
# kernels can fetch them through the same scalar-prefetch page-table
# indirection as the pages themselves, and per-kv-head because head norms
# differ by orders of magnitude while positions within a page do not.

def kv_quant_dtype(kind: Optional[str]):
    """Pool storage dtype for a ``ModelConfig.kv_quant`` kind."""
    if kind is None:
        return None
    if kind == "int8":
        return jnp.int8
    if kind == "fp8":
        return jnp.float8_e4m3fn
    raise ValueError(f"unknown kv quant kind {kind!r}")


def kv_quant_qmax(dtype) -> float:
    """Largest representable code magnitude (amax maps onto it)."""
    if jnp.dtype(dtype) == jnp.int8:
        return 127.0
    return 448.0          # float8_e4m3fn finite max


def quantize_kv(x: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Encode values as codes at ``scale`` (broadcastable): int8 rounds
    and saturates; fp8 stores ``value / scale`` directly (the e4m3 cast
    rounds).  A zero scale (all-zero page) encodes zeros."""
    qmax = kv_quant_qmax(dtype)
    v = jnp.where(scale > 0, x.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-30), 0.0)
    v = jnp.clip(v, -qmax, qmax)
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.round(v).astype(jnp.int8)
    return v.astype(dtype)


def _requant_codes(codes: jax.Array, old_scale: jax.Array,
                   new_scale: jax.Array) -> jax.Array:
    """Re-encode existing page codes after their scale grew (monotone
    scale update): ``code * old / new``.  When the scale is unchanged the
    ratio is exactly 1.0 and the round-trip is the identity, so steady
    appends never drift a page's earlier rows."""
    ratio = jnp.where(new_scale > 0,
                      old_scale / jnp.maximum(new_scale, 1e-30), 0.0)
    v = codes.astype(jnp.float32) * ratio
    if jnp.dtype(codes.dtype) == jnp.int8:
        return jnp.round(v).astype(jnp.int8)
    return v.astype(codes.dtype)


def cow_copy_pool(pool: jax.Array, src: jax.Array,
                  dst: jax.Array) -> jax.Array:
    """Copy physical page(s) ``src`` onto ``dst`` inside a pool.

    pool: [P, page_size, H, hd]; src/dst: int32 scalars or [N] vectors of
    physical page ids.  The copy-on-write primitive: a shared page is
    duplicated into a freshly allocated one *before* the first divergent
    write, so the writer mutates its private copy and every other
    referent keeps reading the original.  Slots with nothing to copy pass
    ``src == dst == NULL_PAGE`` — the NULL page is copied onto itself, a
    no-op (duplicate NULL entries in a vectorized call all write the same
    content, so the scatter stays deterministic).
    """
    return pool.at[dst].set(pool[src])


def paged_append(pool: jax.Array, page_table: jax.Array, pos: jax.Array,
                 new: jax.Array, *, layout: str,
                 cow_src: Optional[jax.Array] = None,
                 cow_dst: Optional[jax.Array] = None) -> jax.Array:
    """Scatter one decode token per slot into its page.

    pool: [P, page_size, H, hd]; page_table: [B, max_pages] int32;
    pos: [B] absolute write positions; new: [B, 1, H, hd] ("bshd") or
    [B, H, 1, hd] ("bhsd").  Unallocated table entries resolve to the NULL
    page, and a position at/past the table's extent routes to the NULL
    page too — an over-run scan tick (or a slot deliberately parked past
    capacity while it is still prefilling) lands in the sacrificial page
    instead of silently rewriting the slot's last real KV row.  The
    scatter is therefore always in bounds and never corrupts live data.

    Copy-on-write path: when a slot's write position lands inside a page
    it does NOT own exclusively (a prefix-shared page — including the
    partial-last-page case where a prompt ends mid-page and decode
    appends into the shared tail page), pass per-slot ``cow_src`` /
    ``cow_dst`` [B] vectors: each slot's ``cow_src`` page is copied onto
    its ``cow_dst`` page *before* the scatter (``NULL_PAGE`` pairs no-op),
    and ``page_table`` must already point at ``cow_dst`` so the write —
    and every later read — resolves to the private copy.
    """
    page_size = pool.shape[1]
    b = page_table.shape[0]
    if cow_src is not None:
        pool = cow_copy_pool(pool, cow_src, cow_dst)
    tok = to_page_major(new, layout)[:, 0]                 # [B, H, hd]
    extent = page_table.shape[1] * page_size
    in_range = jnp.logical_and(pos >= 0, pos < extent)
    posc = jnp.clip(pos, 0, extent - 1)
    phys = jnp.where(in_range,
                     page_table[jnp.arange(b), posc // page_size],
                     NULL_PAGE)                            # [B]
    return pool.at[phys, posc % page_size].set(tok.astype(pool.dtype))


def _append_row_q(pool: jax.Array, scale: jax.Array,
                  page_table: jax.Array, pos: jax.Array,
                  tok: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantize-on-write core: one page-major token row per slot.

    pool: [P, page_size, H, hd] codes; scale: [P, H] f32; tok: [B, H, hd]
    full-precision.  Per-page scales are MONOTONE non-decreasing: the new
    scale is ``max(old, amax(tok)/qmax)``, and when it grows the page's
    existing rows are re-encoded at the new scale in the same scatter
    (error ~1 code LSB — bounded by the round-trip tests).  NULL routing
    matches ``paged_append``: out-of-range positions write the
    sacrificial page's codes and scale, which nothing dequantizes.
    """
    page_size = pool.shape[1]
    b = page_table.shape[0]
    extent = page_table.shape[1] * page_size
    in_range = jnp.logical_and(pos >= 0, pos < extent)
    posc = jnp.clip(pos, 0, extent - 1)
    phys = jnp.where(in_range,
                     page_table[jnp.arange(b), posc // page_size],
                     NULL_PAGE)                            # [B]
    qmax = kv_quant_qmax(pool.dtype)
    amax = jnp.max(jnp.abs(tok.astype(jnp.float32)), axis=-1)   # [B, H]
    old = scale[phys]                                           # [B, H]
    new = jnp.maximum(old, amax / qmax)
    page = _requant_codes(pool[phys], old[:, None, :, None],
                          new[:, None, :, None])     # [B, ps, H, hd]
    row = quantize_kv(tok, new[..., None], pool.dtype)
    pool = pool.at[phys].set(page)
    pool = pool.at[phys, posc % page_size].set(row)
    return pool, scale.at[phys].set(new)


def paged_append_q(pool: jax.Array, scale: jax.Array,
                   page_table: jax.Array, pos: jax.Array, new: jax.Array,
                   *, layout: str,
                   cow_src: Optional[jax.Array] = None,
                   cow_dst: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, jax.Array]:
    """Quantized twin of ``paged_append``: scatter one decode token per
    slot as codes and fold its magnitude into the page's scale.  Returns
    ``(pool, scale)``.  The COW copy duplicates the scale row alongside
    the value page — the two pools move in lockstep by construction."""
    if cow_src is not None:
        pool = cow_copy_pool(pool, cow_src, cow_dst)
        scale = cow_copy_pool(scale, cow_src, cow_dst)
    tok = to_page_major(new, layout)[:, 0]                 # [B, H, hd]
    return _append_row_q(pool, scale, page_table, pos, tok)


def paged_append_window(pool: jax.Array, page_table: jax.Array,
                        pos: jax.Array, new: jax.Array, *, layout: str,
                        cow_src: Optional[jax.Array] = None,
                        cow_dst: Optional[jax.Array] = None) -> jax.Array:
    """Scatter a W-token verify window per slot into its pages.

    The speculative-decoding sibling of ``paged_append``: ``new`` carries
    ``W = k + 1`` rows per slot ([B, W, H, hd] "bshd" / [B, H, W, hd]
    "bhsd") written at absolute positions ``pos[b] .. pos[b] + W - 1``.
    The same NULL routing applies per row — any row at/past the table
    extent (or a negative position: an inactive slot parked at ``pos=-1``)
    lands in the sacrificial page — so a verify window that overruns a
    slot's capacity degrades into sink writes instead of corrupting live
    K/V.  A window may straddle a page boundary; each row resolves its own
    physical page, so no alignment between ``pos`` and the page grid is
    required.  COW pairs behave exactly as in ``paged_append`` (the
    engine's pre-scan already swapped the table entry to ``cow_dst``).

    The rows past the accepted prefix are STALE after acceptance: the
    engine rolls the slot's extent back (``rollback_extent``) and later
    writes overwrite them; reads in between are masked by ``lengths``.
    """
    page_size = pool.shape[1]
    b = page_table.shape[0]
    if cow_src is not None:
        pool = cow_copy_pool(pool, cow_src, cow_dst)
    win = to_page_major(new, layout)                       # [B, W, H, hd]
    w = win.shape[1]
    extent = page_table.shape[1] * page_size
    p = pos[:, None] + jnp.arange(w)[None, :]              # [B, W]
    in_range = jnp.logical_and(p >= 0, p < extent)
    pc = jnp.clip(p, 0, extent - 1)
    phys = jnp.where(
        in_range,
        page_table[jnp.arange(b)[:, None], pc // page_size],
        NULL_PAGE)                                         # [B, W]
    return pool.at[phys, pc % page_size].set(win.astype(pool.dtype))


def paged_append_window_q(pool: jax.Array, scale: jax.Array,
                          page_table: jax.Array, pos: jax.Array,
                          new: jax.Array, *, layout: str,
                          cow_src: Optional[jax.Array] = None,
                          cow_dst: Optional[jax.Array] = None,
                          ) -> Tuple[jax.Array, jax.Array]:
    """Quantized twin of ``paged_append_window``: the W verify rows are
    appended sequentially through the single-row quantize-on-write core
    (W is small and static), so a window that grows its page's scale
    re-encodes earlier rows exactly as single-token decode would."""
    if cow_src is not None:
        pool = cow_copy_pool(pool, cow_src, cow_dst)
        scale = cow_copy_pool(scale, cow_src, cow_dst)
    win = to_page_major(new, layout)                       # [B, W, H, hd]
    for i in range(win.shape[1]):
        pool, scale = _append_row_q(pool, scale, page_table, pos + i,
                                    win[:, i])
    return pool, scale


def place_chunk_pages_q(pool: jax.Array, scale: jax.Array, seq: jax.Array,
                        chunk_pages: jax.Array, *, layout: str,
                        cow_src: Optional[jax.Array] = None,
                        cow_dst: Optional[jax.Array] = None,
                        ) -> Tuple[jax.Array, jax.Array]:
    """Quantized twin of ``place_chunk_pages``: whole pages are encoded at
    scales computed from their own content (``amax/qmax`` per page per kv
    head) — chunk placement always overwrites whole pages, so the scale
    is SET, not folded; later decode appends into a partial last page go
    through the monotone ``paged_append_q`` update."""
    page_size = pool.shape[1]
    if cow_src is not None:
        pool = cow_copy_pool(pool, cow_src, cow_dst)
        scale = cow_copy_pool(scale, cow_src, cow_dst)
    x = to_page_major(seq, layout)[0]                      # [C, H, hd]
    c, h, hd = x.shape
    chunks = x.reshape(c // page_size, page_size, h, hd)
    qmax = kv_quant_qmax(pool.dtype)
    amax = jnp.max(jnp.abs(chunks.astype(jnp.float32)),
                   axis=(1, 3))                            # [n_cp, H]
    new = amax / qmax
    codes = quantize_kv(chunks, new[:, None, :, None], pool.dtype)
    return (pool.at[chunk_pages].set(codes),
            scale.at[chunk_pages].set(new))


def gather_pages_dequant(pool: jax.Array, scale: jax.Array,
                         page_table: jax.Array, *,
                         layout: str) -> jax.Array:
    """Quantized twin of ``gather_pages``: materialize dense f32 K/V by
    dequantizing each gathered page with its per-(page, head) scale —
    the eager reference the quantized Pallas kernels must match."""
    pages = pool[page_table].astype(jnp.float32)  # [B, n, ps, H, hd]
    s = scale[page_table]                         # [B, n, H]
    pages = pages * s[:, :, None, :, None]
    b, n, ps, h, hd = pages.shape
    return from_page_major(pages.reshape(b, n * ps, h, hd), layout)


def live_page_table(page_table: jax.Array, lengths, page_size: int
                    ) -> jax.Array:
    """Re-route table entries wholly past the live prefix to the NULL page.

    page_table: [max_pages] (one slot) or [B, max_pages]; lengths: the
    matching scalar or [B] valid-token counts (may be traced).  Bounds KV
    traffic for the gather paths the same way the offset flash kernel's
    index-map clamp bounds its DMA: a gather through the clamped table
    touches O(live prefix) distinct pages — the dead tail all reads the
    one (cache-resident) NULL page — and correctness is unchanged because
    every consumer already masks scores at the valid length.
    """
    live = (jnp.asarray(lengths) + page_size - 1) // page_size
    idx = jnp.arange(page_table.shape[-1])
    if page_table.ndim == 2:
        mask = idx[None] < jnp.reshape(live, (-1, 1))
    else:
        mask = idx < live
    return jnp.where(mask, page_table, NULL_PAGE)


def gather_pages(pool: jax.Array, page_table: jax.Array, *,
                 layout: str) -> jax.Array:
    """Materialize per-slot contiguous K/V from the pool (reference path).

    pool: [P, page_size, H, hd] -> [B, max_pages*page_size, H, hd]
    ("bshd") or [B, H, S, hd] ("bhsd").  Entries past a slot's length read
    whatever its (or the NULL) pages hold; callers mask by length exactly
    as with the contiguous cache.
    """
    pages = pool[page_table]                      # [B, max_pages, ps, H, hd]
    b, n, ps, h, hd = pages.shape
    return from_page_major(pages.reshape(b, n * ps, h, hd), layout)


def place_prefill(cache: Tree, fresh: Tree, slot: jax.Array,
                  pages: jax.Array, *, layout: str) -> Tree:
    """Write one request's prefill cache into the paged pools.

    ``fresh`` is a batch-1 prefill cache ([G, 1, ...] leaves).  K/V leaves
    are chunked into pages and scattered to the physical ``pages`` of this
    slot; state leaves replace the slot row.  Runs inside a donated jit —
    both scatters update in place.

    Quantized pools carry ``*_scale`` siblings the fresh (full-precision)
    prefill cache does not have, so the walk is over the parallel dict
    structures rather than a ``tree_map``: each K/V leaf's pages are
    encoded at their own per-(page, head) scales and the sibling scale
    pool rows are written in the same pass (freshly ``ensure``d pages —
    the scale is set, never folded).
    """
    page_size = None
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            page_size = leaf.shape[2]
            break

    def place_dict(cd: dict, fd: dict) -> dict:
        out = dict(cd)
        for name, small in fd.items():
            kind = cache_leaf_kind(name)
            pool = cd[name]
            if kind == "state":
                out[name] = pool.at[:, slot].set(
                    small[:, 0].astype(pool.dtype))
                continue
            seq = to_page_major(small, layout)[:, 0]       # [G, S, H, hd]
            g, s, h, hd = seq.shape
            n = pages.shape[0]
            pad = n * page_size - s
            if pad:
                seq = jnp.pad(seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
            chunks = seq.reshape(g, n, page_size, h, hd)
            sname = name + "_scale"
            if sname in cd:
                qmax = kv_quant_qmax(pool.dtype)
                amax = jnp.max(jnp.abs(chunks.astype(jnp.float32)),
                               axis=(2, 4))                # [G, n, H]
                new = amax / qmax
                codes = quantize_kv(chunks, new[:, :, None, :, None],
                                    pool.dtype)
                out[name] = pool.at[:, pages].set(codes)
                out[sname] = cd[sname].at[:, pages].set(new)
            else:
                out[name] = pool.at[:, pages].set(chunks.astype(pool.dtype))
        return out

    return {
        "blocks": tuple(place_dict(c, f) for c, f
                        in zip(cache["blocks"], fresh["blocks"])),
        "rest": tuple(place_dict(c, f) for c, f
                      in zip(cache["rest"], fresh["rest"])),
    }


def place_chunk_pages(pool: jax.Array, seq: jax.Array,
                      chunk_pages: jax.Array, *, layout: str,
                      cow_src: Optional[jax.Array] = None,
                      cow_dst: Optional[jax.Array] = None) -> jax.Array:
    """Page-aligned incremental prefill placement: write ONE chunk's K/V
    into its physical pages.

    pool: [P, page_size, H, hd]; seq: a batch-1 chunk [1, C, H, hd]
    ("bshd") or [1, H, C, hd] ("bhsd"); chunk_pages: [C // page_size]
    int32 physical page ids for the chunk's logical pages.  The chunk size
    is a whole multiple of the page size by construction (the engine
    aligns the chunk grid to the page grid), so the write is a whole-page
    scatter — no read-modify-write of partially-filled pages.  Entries of
    ``chunk_pages`` past the slot's capacity carry the NULL page and land
    in the sacrificial page (pad tokens of the final chunk).  Runs inside
    a donated jit: the scatter updates the pool in place.

    Copy-on-write path: when the chunk's span includes a page the slot
    claimed from the prefix cache rather than allocating fresh (a prompt
    whose divergence point sits mid-page), pass scalar ``cow_src`` /
    ``cow_dst``: the shared page is copied onto the private ``cow_dst``
    page before the chunk scatter (``NULL_PAGE`` pair no-ops), keeping
    the state machine uniform — a shared page is never a scatter target;
    ``chunk_pages`` must already carry ``cow_dst``.
    """
    page_size = pool.shape[1]
    if cow_src is not None:
        pool = cow_copy_pool(pool, cow_src, cow_dst)
    x = to_page_major(seq, layout)[0]                      # [C, H, hd]
    c, h, hd = x.shape
    chunks = x.reshape(c // page_size, page_size, h, hd)
    return pool.at[chunk_pages].set(chunks.astype(pool.dtype))


def stage_chunk(prompt: np.ndarray, off: int, chunk: int,
                row: np.ndarray, page_size: int):
    """Host-side staging of one prefill chunk for ``prefill_chunk``.

    prompt: [S] tokens; off: chunk start — any PAGE-aligned offset (the
    prefix cache resumes prefill at the first non-cached page, which
    need not sit on the chunk grid); row:
    the slot's page-table row (after ``ensure``); returns ``(tokens
    [chunk] zero-padded past the prompt, chunk_pages [chunk // page_size]
    physical ids with NULL past the table extent, last_idx)`` where
    ``last_idx`` is the within-chunk index of the prompt's final real
    token (clamped; only meaningful on the final chunk).  Shared by the
    engine and the tests so the staging contract lives in one place.
    """
    n_cp = chunk // page_size
    j0 = off // page_size
    cpages = np.full(n_cp, NULL_PAGE, np.int32)
    n = max(0, min(n_cp, int(row.shape[0]) - j0))
    cpages[:n] = row[j0:j0 + n]
    toks = np.zeros(chunk, np.int32)
    seg = prompt[off:off + chunk]
    toks[:len(seg)] = seg
    last = min(int(prompt.shape[0]) - 1 - off, chunk - 1)
    return toks, cpages, last


# --------------------------------------------------------------------- #
# Pool construction
# --------------------------------------------------------------------- #

def paged_cache_defs(cfg: ModelConfig, slots: int, max_len: int,
                     page_size: int) -> Tree:
    """Cache definition tree with K/V leaves replaced by page pools.

    Under a KV ``QuantMode`` each K/V pool stores int8 / fp8 codes and
    gains a sibling ``<name>_scale`` leaf ``[G, num_pages, Hkv]`` f32 —
    one scale per (physical page, kv head), indexed by the same page ids
    as the pool (DESIGN.md §14).
    """
    num_pages = 1 + slots * cdiv(max_len, page_size)       # +1: NULL page
    hkv, hd = cfg.num_kv_heads, cfg.head_dim_
    qdtype = kv_quant_dtype(cfg.kv_quant)

    def group_defs(defs: dict) -> dict:
        out = {}
        for name, cd in defs.items():
            if cache_leaf_kind(name) == "state":
                out[name] = cd
                continue
            groups = cd.shape[0]
            out[name] = CacheDef(
                (groups, num_pages, page_size, hkv, hd),
                ("layers", "kv_pages", None, "kv_heads", None),
                qdtype if qdtype is not None else cd.dtype)
            if qdtype is not None:
                out[name + "_scale"] = CacheDef(
                    (groups, num_pages, hkv),
                    ("layers", "kv_pages", "kv_heads"), jnp.float32)
        return out

    base = cache_defs(cfg, slots, max_len)
    return {"blocks": tuple(group_defs(d) for d in base["blocks"]),
            "rest": tuple(group_defs(d) for d in base["rest"])}


class PagedKVCache:
    """Device page pools + page table + host-side refcounted allocator.

    The device state (``cache`` pytree, ``page_table``) flows through the
    engine's donated dispatches; this object owns the *allocation* state:
    which physical pages belong to which slot, and which are free.  The
    page table itself is kept as host numpy (tiny) and re-uploaded per
    dispatch — allocation happens between dispatches, never inside jit.

    Ownership is refcounted so the prefix cache can point several slots
    at one physical page (DESIGN.md §10).  Page states:

      * **free** — on ``_free``, ``refs == 0``, not tree-owned.
      * **referenced** — ``refs`` = number of slots whose table rows
        carry the page.  ``ensure`` allocates exclusively (``refs = 1``);
        ``adopt_shared`` claims an existing page (``refs += 1``).
      * **cached** — ``refs == 0`` but owned by the prefix tree
        (``mark_tree``): the page keeps its K/V after every referencing
        slot exited, and is reclaimed through ``evict_page`` (driven by
        the ``evictor`` hook when the free list runs dry).

    ``release`` moves a slot's references down exactly once: a page drops
    to the free list only when its refcount hits zero AND the tree does
    not own it — a shared or cached page can therefore never be
    double-freed, and ``assert_page_accounting`` verifies the partition
    (every physical page in exactly one state, the free list duplicate-
    free, refcounts equal to actual table occupancy).

    Bytes accounting counts a shared page ONCE: ``pages_in_use`` is the
    number of *distinct* referenced pages, so the paged-memory metrics
    (and the per-shard split under a mesh) report physical truth.
    """

    def __init__(self, cfg: ModelConfig, *, slots: int, max_len: int,
                 page_size: int = 16, mesh=None, obs=NULL_RECORDER):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.cfg = cfg
        # Telemetry recorder (obs/events.py): page lifecycle instants on
        # the "kv" track.  NULL_RECORDER no-ops; emission sites guard on
        # ``enabled`` so the disabled path never builds argument dicts.
        self.obs = obs
        self.slots = slots
        self.max_len = max_len
        self.page_size = min(page_size, max_len)
        self.pages_per_slot = cdiv(max_len, self.page_size)
        self.num_pages = 1 + slots * self.pages_per_slot
        self._defs = paged_cache_defs(cfg, slots, max_len, self.page_size)
        # Mesh-aware pool layout (DESIGN.md §9): K/V pools shard over the
        # model axis at their ``kv_heads`` dim — resolved through the same
        # logical-axis rules as the parameters, so a head count that does
        # not divide falls back to replication.  The page table (and the
        # slot-contiguous state leaves) stay replicated: every shard
        # resolves the same logical->physical page indirection and only
        # streams its own heads' slice of each page.
        self.mesh = mesh
        self.kv_shards = 1
        self._shardings: Optional[Tree] = None
        if mesh is not None:
            from ..distributed.sharding import spec_for

            def leaf_sharding(path, cd):
                # Scale pools shard alongside their value pools (both
                # carry a ``kv_heads`` logical axis); state stays
                # replicated.
                if cache_leaf_kind(cache_leaf_name(path)) \
                        not in ("kv", "scale"):
                    return NamedSharding(mesh, P())
                return NamedSharding(
                    mesh, spec_for(cfg, cd.axes, cd.shape, mesh))

            self._shardings = jax.tree_util.tree_map_with_path(
                leaf_sharding, self._defs,
                is_leaf=lambda x: isinstance(x, CacheDef))
            def claims_model(spec) -> bool:
                return any(e == "model"
                           or (isinstance(e, tuple) and "model" in e)
                           for e in spec)

            for s in jax.tree.leaves(
                    self._shardings,
                    is_leaf=lambda x: isinstance(x, NamedSharding)):
                if claims_model(s.spec):
                    self.kv_shards = int(mesh.shape["model"])
                    break
        # Bytes of ONE physical page summed over every page-indexed pool
        # leaf (all layer groups) — the unit of the bytes-in-use
        # accounting.  Computed from each pool's ACTUAL dtype, not an
        # assumed uniform compute dtype: quantized value pools count at
        # the int8/fp8 itemsize and the f32 scale pools count too, so
        # ``bytes_in_use``/``peak_bytes_per_shard`` report physical truth
        # across quant modes.  Every leaf with a ``kv_pages`` axis (dim 1)
        # contributes ``elems / num_pages * itemsize``.
        self.page_bytes = 0
        self._kv_elems_per_page = 0
        for path, cd in jax.tree_util.tree_flatten_with_path(
                self._defs, is_leaf=lambda x: isinstance(x, CacheDef))[0]:
            kind = cache_leaf_kind(cache_leaf_name(path))
            if kind not in ("kv", "scale"):
                continue
            per_page = int(np.prod(cd.shape)) // cd.shape[1]
            self.page_bytes += per_page * jnp.dtype(cd.dtype).itemsize
            if kind == "kv":
                self._kv_elems_per_page += per_page
        self._table = np.zeros((slots, self.pages_per_slot), np.int32)
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        # Refcounts (slot references per physical page) + the set of
        # pages the prefix tree owns (kept out of the free list at ref 0).
        self._refs = np.zeros(self.num_pages, np.int64)
        self._in_use = 0            # distinct pages with refs > 0,
        #                             maintained on 0<->1 transitions
        self._tree: set = set()
        # Called when the free list runs dry: must reclaim >= 1 cached
        # page (via ``evict_page``) and return True, or return False.
        self.evictor: Optional[Callable[[], bool]] = None
        self.peak_pages = 0

    def init_cache(self) -> Tree:
        """Fresh device cache tree (paged pools + slot-contiguous state).
        The engine owns it from here: it is donated through every dispatch
        and this object only tracks which pages are whose.  With a mesh,
        every leaf is placed under its ``NamedSharding`` (K/V pools
        ``kv_heads``-sharded over 'model', the rest replicated)."""
        if self._shardings is None:
            return jax.tree.map(
                lambda cd: jnp.zeros(cd.shape, cd.dtype), self._defs,
                is_leaf=lambda x: isinstance(x, CacheDef))
        return jax.tree.map(
            lambda cd, ns: jax.device_put(jnp.zeros(cd.shape, cd.dtype), ns),
            self._defs, self._shardings,
            is_leaf=lambda x: isinstance(x, CacheDef))

    # ------------------------------------------------------------ state
    @property
    def page_table(self) -> jax.Array:
        t = jnp.asarray(self._table)
        if self.mesh is not None:
            t = jax.device_put(t, NamedSharding(self.mesh, P(None, None)))
        return t

    @property
    def pages_in_use(self) -> int:
        """Distinct physical pages referenced by slots — a page shared by
        k slots counts ONCE (it exists once in the pools).  Maintained
        incrementally on refcount 0<->1 transitions (the allocation hot
        path reads it per page via the peak update)."""
        return self._in_use

    def _ref(self, page: int) -> None:
        if self._refs[page] == 0:
            self._in_use += 1
        self._refs[page] += 1

    def _deref(self, page: int) -> None:
        self._refs[page] -= 1
        assert self._refs[page] >= 0, f"double release of page {page}"
        if self._refs[page] == 0:
            self._in_use -= 1
            if page not in self._tree:
                self.free_page(page)

    @property
    def pages_cached(self) -> int:
        """Tree-owned pages no slot references: K/V kept warm for future
        prefix hits, reclaimable by eviction."""
        return sum(1 for p in self._tree if self._refs[p] == 0)

    @property
    def bytes_in_use(self) -> int:
        return self.pages_in_use * self.page_bytes

    @property
    def bytes_cached(self) -> int:
        return self.pages_cached * self.page_bytes

    @property
    def peak_bytes_in_use(self) -> int:
        return self.peak_pages * self.page_bytes

    @property
    def peak_bytes_per_shard(self) -> int:
        """Per-device peak K/V bytes: the pools split over ``kv_shards``
        (the 'model' factor the kv_heads dim actually claimed)."""
        return self.peak_bytes_in_use // self.kv_shards

    @property
    def kv_itemsize_effective(self) -> float:
        """Stored bytes per K/V element, scale-pool overhead amortized in
        (e.g. bf16 -> 2.0; int8 with per-page-per-head f32 scales ->
        slightly above 1.0).  Self-describing unit for cross-quant-mode
        bytes comparisons in the metrics and benchmarks."""
        return self.page_bytes / self._kv_elems_per_page

    def slot_pages(self, slot: int) -> np.ndarray:
        return np.asarray(self._owned[slot], np.int32)

    def table_row(self, slot: int) -> np.ndarray:
        """One slot's logical->physical page map (unallocated: NULL)."""
        return self._table[slot].copy()

    @property
    def extent(self) -> int:
        """Positions addressable through the table (>= max_len; a write at
        or past this routes to the NULL page in ``paged_append``)."""
        return self.pages_per_slot * self.page_size

    def page_refs(self, page: int) -> int:
        return int(self._refs[page])

    # ------------------------------------------------------- allocation
    def alloc_page(self) -> int:
        """Pop one free page, evicting cached prefix pages through the
        ``evictor`` hook when the free list is dry.  Raises when every
        page is referenced — steady-state demand fits the pool (per-slot
        demand caps at ``pages_per_slot`` and sharing only lowers it),
        but a copy-on-write needs ONE transient extra page while both
        src and dst are live, so a fully-referenced pool can legally
        fail here; callers on the serving path catch and fail the one
        request instead of the stream."""
        while not self._free:
            if self.evictor is None or not self.evictor():
                raise RuntimeError(
                    f"KV page pool exhausted ({self.num_pages - 1} pages)")
        page = self._free.pop()
        if self.obs.enabled:
            self.obs.instant(PAGE_ALLOC, track=TRACK_KV, page=page,
                             free=len(self._free))
        return page

    def free_page(self, page: int) -> None:
        assert self._refs[page] == 0 and page != NULL_PAGE
        self._free.append(page)
        if self.obs.enabled:
            self.obs.instant(PAGE_FREE, track=TRACK_KV, page=page,
                             free=len(self._free))

    def ensure(self, slot: int, length: int) -> np.ndarray:
        """Allocate pages so ``slot`` can hold ``length`` tokens; returns
        the slot's physical pages.  ``length`` beyond ``max_len`` raises —
        the pool is sized for ``slots * max_len`` exactly.  Logical pages
        already populated (freshly allocated earlier, or prefix-shared
        via ``adopt_shared``) are kept; only the tail is allocated."""
        if length > self.max_len:
            raise ValueError(
                f"cannot ensure {length} tokens: slot capacity is "
                f"max_len={self.max_len}")
        need = cdiv(max(length, 1), self.page_size)
        owned = self._owned[slot]
        while len(owned) < need:
            page = self.alloc_page()
            self._ref(page)
            self._table[slot, len(owned)] = page
            owned.append(page)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return self.slot_pages(slot)

    def adopt_shared(self, slot: int, page: int) -> int:
        """Claim an existing (tree-cached or other-slot) page as this
        slot's next logical page: bump its refcount and write the shared
        physical id straight into the slot's table row.  Returns the
        logical index.  Prefix pages are adopted in walk order BEFORE any
        ``ensure`` so logical order matches token order."""
        owned = self._owned[slot]
        logical = len(owned)
        self._ref(page)
        self._table[slot, logical] = page
        owned.append(page)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return logical

    def cow_page(self, slot: int, logical: int) -> Tuple[int, int]:
        """Copy-on-write swap: replace the slot's shared logical page
        with a fresh exclusive one.  Returns ``(src, dst)`` physical ids
        for the in-jit page copy (``cow_src``/``cow_dst`` operands); the
        host table row already points at ``dst`` when this returns.  The
        slot's reference MOVES: ``src`` drops one ref (staying cached if
        the tree owns it), ``dst`` starts at one."""
        src = self._owned[slot][logical]
        dst = self.alloc_page()
        self._ref(dst)
        self._deref(src)               # stays cached when tree-owned
        self._owned[slot][logical] = dst
        self._table[slot, logical] = dst
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        if self.obs.enabled:
            self.obs.instant(PAGE_COW, track=TRACK_KV, slot=slot,
                             src=src, dst=dst, logical=logical)
        return src, dst

    def rollback_extent(self, slot: int, length: int) -> int:
        """Truncate a slot's extent to ``length`` tokens after a rejected
        speculative draft, releasing the freshly-appended tail pages
        exactly once.  Returns the number of pages released.

        Only pages WHOLLY past ``length`` are dropped; a partial last page
        is kept (its stale tail rows are masked by the slot's length and
        overwritten by later appends).  The engine never rolls back below
        the prompt — draft rows are appended strictly after the prefill
        extent — so every truncated page was allocated exclusively for
        draft K/V this pass.  That invariant is ASSERTED here rather than
        assumed: a truncated page must be exclusively owned (``refs == 1``)
        and not tree-owned, i.e. the prefix cache can never lose a shared
        or cached page to a rollback, and a rolled-back partial page can
        never have been adopted into the radix tree (``PrefixCache.insert``
        only indexes full prompt pages, which rollback never touches).
        """
        keep = cdiv(max(length, 1), self.page_size)
        owned = self._owned[slot]
        dropped = 0
        while len(owned) > keep:
            page = owned[-1]
            # Check BEFORE popping: a refused rollback must leave the
            # allocator untouched, not half-truncated.
            assert self._refs[page] == 1 and page not in self._tree, \
                (f"rollback of slot {slot} would release page {page} "
                 f"(refs={int(self._refs[page])}, "
                 f"tree={page in self._tree}) — draft pages must be "
                 f"exclusive and never tree-adopted")
            owned.pop()
            self._table[slot, len(owned)] = NULL_PAGE
            self._deref(page)
            dropped += 1
        if dropped and self.obs.enabled:
            self.obs.instant(PAGE_ROLLBACK, track=TRACK_KV, slot=slot,
                             pages=dropped, length=length)
        return dropped

    # ------------------------------------------------- tree page custody
    def mark_tree(self, page: int) -> None:
        """Hand custody of a page to the prefix tree: at refcount zero it
        stays CACHED (not freed) until ``evict_page`` reclaims it."""
        self._tree.add(page)

    def evict_page(self, page: int) -> None:
        """Tree eviction: reclaim a cached (ref-0, tree-owned) page."""
        assert page in self._tree and self._refs[page] == 0
        self._tree.discard(page)
        if self.obs.enabled:
            self.obs.instant(PAGE_EVICT, track=TRACK_KV, page=page)
        self.free_page(page)

    def disown(self, page: int) -> None:
        """Revoke tree custody WITHOUT freeing: a pruned subtree's
        still-referenced pages keep serving their slots and return to
        the free list normally when the last reference drops."""
        self._tree.discard(page)

    def release(self, slot: int) -> None:
        """Drop a finished slot's page references exactly once and point
        its table row back at the NULL page.  Exclusive pages return to
        the free list; shared pages just lose one reference; tree-owned
        pages stay cached at refcount zero (the prefix tree keeps their
        K/V warm until memory pressure evicts them).  Idempotent: a
        second release of the same slot is a no-op (``_owned`` already
        empty), so an engine error path can never double-free."""
        for page in reversed(self._owned[slot]):
            self._deref(page)
        self._owned[slot] = []
        self._table[slot, :] = NULL_PAGE

    # ------------------------------------------------------- invariants
    def assert_page_accounting(self, cache: Optional[Tree] = None) -> None:
        """Free-list / refcount / tree partition invariant (used by the
        churn tests and the engine's debug hooks).

        Every physical page (except NULL) is in exactly one state:
        free, referenced (refs > 0), or cached (tree-owned at refs 0);
        the free list holds no duplicates and nothing referenced or
        tree-owned; refcounts equal actual slot-table occupancy.

        Under a KV quant mode, additionally cross-checks that the value
        and scale pools stay in LOCKSTEP: every K/V leaf has a
        ``<name>_scale`` sibling indexed by the same physical page axis
        (``[G, num_pages, Hkv]`` f32) — and, when the live device
        ``cache`` tree is passed, that its leaves match the definitions.
        Since every page mutation (append, chunk placement, COW copy,
        prefill placement) goes through paired pool+scale primitives
        addressed by one shared page id, shape lockstep plus the single
        allocator are what make a value page and its scale row move
        together."""
        free = list(self._free)
        free_set = set(free)
        assert len(free) == len(free_set), "free list holds duplicates"
        assert NULL_PAGE not in free_set, "NULL page on the free list"
        counts = np.zeros(self.num_pages, np.int64)
        for owned in self._owned:
            for page in owned:
                counts[page] += 1
        assert np.array_equal(counts, self._refs), \
            "refcounts disagree with slot ownership"
        referenced = {p for p in range(1, self.num_pages)
                      if self._refs[p] > 0}
        assert self._in_use == len(referenced), \
            "incremental in-use counter drifted"
        cached = {p for p in self._tree if self._refs[p] == 0}
        assert not (free_set & referenced), "referenced page on free list"
        assert not (free_set & cached), "cached page on free list"
        assert free_set | referenced | cached \
            == set(range(1, self.num_pages)), "page leaked (no state)"
        # Table rows mirror ownership: owned prefix, NULL beyond.
        for slot, owned in enumerate(self._owned):
            assert list(self._table[slot, :len(owned)]) == owned
            assert np.all(self._table[slot, len(owned):] == NULL_PAGE)
        # Value/scale pool lockstep (DESIGN.md §14).
        quant = self.cfg.kv_quant is not None
        for group in self._defs["blocks"] + self._defs["rest"]:
            for name, cd in group.items():
                if cache_leaf_kind(name) != "kv":
                    continue
                sname = name + "_scale"
                if not quant:
                    assert sname not in group, \
                        f"unexpected scale pool {sname} without kv quant"
                    continue
                assert sname in group, f"missing scale pool {sname}"
                scd = group[sname]
                assert scd.shape == (cd.shape[0], cd.shape[1],
                                     cd.shape[3]), \
                    (f"{sname} shape {scd.shape} out of lockstep with "
                     f"{name} {cd.shape}")
                assert jnp.dtype(scd.dtype) == jnp.float32
                assert jnp.dtype(cd.dtype) == jnp.dtype(
                    kv_quant_dtype(self.cfg.kv_quant))
        if cache is not None:
            flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
            flat_d = jax.tree_util.tree_flatten_with_path(
                self._defs, is_leaf=lambda x: isinstance(x, CacheDef))[0]
            assert len(flat_c) == len(flat_d), \
                "device cache structure out of lockstep with definitions"
            for (pc, leaf), (pd, cd) in zip(flat_c, flat_d):
                assert pc == pd and leaf.shape == cd.shape \
                    and jnp.dtype(leaf.dtype) == jnp.dtype(cd.dtype), \
                    (f"device leaf {pc} {leaf.shape}/{leaf.dtype} vs def "
                     f"{pd} {cd.shape}/{cd.dtype}")
