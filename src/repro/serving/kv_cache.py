"""Paged KV cache: fixed-size pages + slot indirection for decode.

The contiguous slot cache (PR 1) reserves ``slots x max_len`` worst-case
K/V per layer group.  This module pages it, vLLM/TensorRT-LLM style:

  * K/V storage is a *pool* of fixed-size pages per layer group, stored
    page-major and layout-canonical: ``[G, P, page_size, Hkv, hd]``
    regardless of the model's ``kv_cache_layout`` (append/gather adapt at
    the edges, so both "bshd" and "bhsd" configs run paged).
  * A device-resident page table ``[slots, max_pages] int32`` maps each
    slot's logical page j to a physical page id.  Physical page 0 is the
    NULL page: unallocated table entries point at it, so inactive slots'
    decode writes land in a sacrificial page and data-dependent page
    lookups (the Pallas kernel's scalar-prefetch index map) never read out
    of bounds.
  * Pages are allocated from a host-side free list as a slot's sequence
    grows and returned when the request finishes — bytes-in-use is
    ``pages_in_use * page_bytes``, not ``slots * max_len`` worst case.
  * Non-sequence state leaves (SSM / conv / wkv / token-shift) carry no
    sequence axis; they stay slot-contiguous ``[G, slots, ...]`` and are
    whole-replaced per slot.  Leaf classification comes from the shared
    schema in ``models/params.py`` (``cache_leaf_kind``) — an unknown leaf
    raises instead of being silently mishandled.

The functional primitives (``paged_append`` / ``gather_pages`` /
``place_prefill``) are pure: they take and return arrays so the engine can
run them inside donated jits, and ``models/model.py`` calls
``paged_append`` from the decode step when a page table is passed.
"""

from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.params import (CacheDef, cache_defs, cache_leaf_kind,
                             cache_leaf_name)

Tree = Any

NULL_PAGE = 0       # physical page reserved as the write sink for
#                     unallocated table entries / inactive slots


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# --------------------------------------------------------------------- #
# Functional primitives (jit-safe, layout-adapting)
# --------------------------------------------------------------------- #

def to_page_major(seq: jax.Array, layout: str) -> jax.Array:
    """K/V with a batch axis -> canonical [..., S, H, hd] order.

    seq: [B, S, H, hd] ("bshd") or [B, H, S, hd] ("bhsd").
    """
    if layout == "bhsd":
        return jnp.swapaxes(seq, -3, -2)
    return seq


def from_page_major(seq: jax.Array, layout: str) -> jax.Array:
    """Inverse of ``to_page_major``."""
    if layout == "bhsd":
        return jnp.swapaxes(seq, -3, -2)
    return seq


def paged_append(pool: jax.Array, page_table: jax.Array, pos: jax.Array,
                 new: jax.Array, *, layout: str) -> jax.Array:
    """Scatter one decode token per slot into its page.

    pool: [P, page_size, H, hd]; page_table: [B, max_pages] int32;
    pos: [B] absolute write positions; new: [B, 1, H, hd] ("bshd") or
    [B, H, 1, hd] ("bhsd").  Unallocated table entries resolve to the NULL
    page, and a position at/past the table's extent routes to the NULL
    page too — an over-run scan tick (or a slot deliberately parked past
    capacity while it is still prefilling) lands in the sacrificial page
    instead of silently rewriting the slot's last real KV row.  The
    scatter is therefore always in bounds and never corrupts live data.
    """
    page_size = pool.shape[1]
    b = page_table.shape[0]
    tok = to_page_major(new, layout)[:, 0]                 # [B, H, hd]
    extent = page_table.shape[1] * page_size
    in_range = jnp.logical_and(pos >= 0, pos < extent)
    posc = jnp.clip(pos, 0, extent - 1)
    phys = jnp.where(in_range,
                     page_table[jnp.arange(b), posc // page_size],
                     NULL_PAGE)                            # [B]
    return pool.at[phys, posc % page_size].set(tok.astype(pool.dtype))


def live_page_table(page_table: jax.Array, lengths, page_size: int
                    ) -> jax.Array:
    """Re-route table entries wholly past the live prefix to the NULL page.

    page_table: [max_pages] (one slot) or [B, max_pages]; lengths: the
    matching scalar or [B] valid-token counts (may be traced).  Bounds KV
    traffic for the gather paths the same way the offset flash kernel's
    index-map clamp bounds its DMA: a gather through the clamped table
    touches O(live prefix) distinct pages — the dead tail all reads the
    one (cache-resident) NULL page — and correctness is unchanged because
    every consumer already masks scores at the valid length.
    """
    live = (jnp.asarray(lengths) + page_size - 1) // page_size
    idx = jnp.arange(page_table.shape[-1])
    if page_table.ndim == 2:
        mask = idx[None] < jnp.reshape(live, (-1, 1))
    else:
        mask = idx < live
    return jnp.where(mask, page_table, NULL_PAGE)


def gather_pages(pool: jax.Array, page_table: jax.Array, *,
                 layout: str) -> jax.Array:
    """Materialize per-slot contiguous K/V from the pool (reference path).

    pool: [P, page_size, H, hd] -> [B, max_pages*page_size, H, hd]
    ("bshd") or [B, H, S, hd] ("bhsd").  Entries past a slot's length read
    whatever its (or the NULL) pages hold; callers mask by length exactly
    as with the contiguous cache.
    """
    pages = pool[page_table]                      # [B, max_pages, ps, H, hd]
    b, n, ps, h, hd = pages.shape
    return from_page_major(pages.reshape(b, n * ps, h, hd), layout)


def place_prefill(cache: Tree, fresh: Tree, slot: jax.Array,
                  pages: jax.Array, *, layout: str) -> Tree:
    """Write one request's prefill cache into the paged pools.

    ``fresh`` is a batch-1 prefill cache ([G, 1, ...] leaves).  K/V leaves
    are chunked into pages and scattered to the physical ``pages`` of this
    slot; state leaves replace the slot row.  Runs inside a donated jit —
    both scatters update in place.
    """
    page_size = None
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if cache_leaf_kind(cache_leaf_name(path)) == "kv":
            page_size = leaf.shape[2]
            break

    def place(path, pool, small):
        kind = cache_leaf_kind(cache_leaf_name(path))
        if kind == "state":
            return pool.at[:, slot].set(small[:, 0].astype(pool.dtype))
        seq = to_page_major(small, layout)[:, 0]           # [G, S, H, hd]
        g, s, h, hd = seq.shape
        n = pages.shape[0]
        pad = n * page_size - s
        if pad:
            seq = jnp.pad(seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        chunks = seq.reshape(g, n, page_size, h, hd)
        return pool.at[:, pages].set(chunks.astype(pool.dtype))

    return jax.tree_util.tree_map_with_path(place, cache, fresh)


def place_chunk_pages(pool: jax.Array, seq: jax.Array,
                      chunk_pages: jax.Array, *, layout: str) -> jax.Array:
    """Page-aligned incremental prefill placement: write ONE chunk's K/V
    into its physical pages.

    pool: [P, page_size, H, hd]; seq: a batch-1 chunk [1, C, H, hd]
    ("bshd") or [1, H, C, hd] ("bhsd"); chunk_pages: [C // page_size]
    int32 physical page ids for the chunk's logical pages.  The chunk size
    is a whole multiple of the page size by construction (the engine
    aligns the chunk grid to the page grid), so the write is a whole-page
    scatter — no read-modify-write of partially-filled pages.  Entries of
    ``chunk_pages`` past the slot's capacity carry the NULL page and land
    in the sacrificial page (pad tokens of the final chunk).  Runs inside
    a donated jit: the scatter updates the pool in place.
    """
    page_size = pool.shape[1]
    x = to_page_major(seq, layout)[0]                      # [C, H, hd]
    c, h, hd = x.shape
    chunks = x.reshape(c // page_size, page_size, h, hd)
    return pool.at[chunk_pages].set(chunks.astype(pool.dtype))


def stage_chunk(prompt: np.ndarray, off: int, chunk: int,
                row: np.ndarray, page_size: int):
    """Host-side staging of one prefill chunk for ``prefill_chunk``.

    prompt: [S] tokens; off: chunk start (a multiple of ``chunk``); row:
    the slot's page-table row (after ``ensure``); returns ``(tokens
    [chunk] zero-padded past the prompt, chunk_pages [chunk // page_size]
    physical ids with NULL past the table extent, last_idx)`` where
    ``last_idx`` is the within-chunk index of the prompt's final real
    token (clamped; only meaningful on the final chunk).  Shared by the
    engine and the tests so the staging contract lives in one place.
    """
    n_cp = chunk // page_size
    j0 = off // page_size
    cpages = np.full(n_cp, NULL_PAGE, np.int32)
    n = max(0, min(n_cp, int(row.shape[0]) - j0))
    cpages[:n] = row[j0:j0 + n]
    toks = np.zeros(chunk, np.int32)
    seg = prompt[off:off + chunk]
    toks[:len(seg)] = seg
    last = min(int(prompt.shape[0]) - 1 - off, chunk - 1)
    return toks, cpages, last


# --------------------------------------------------------------------- #
# Pool construction
# --------------------------------------------------------------------- #

def paged_cache_defs(cfg: ModelConfig, slots: int, max_len: int,
                     page_size: int) -> Tree:
    """Cache definition tree with K/V leaves replaced by page pools."""
    num_pages = 1 + slots * cdiv(max_len, page_size)       # +1: NULL page
    hkv, hd = cfg.num_kv_heads, cfg.head_dim_

    def to_pool(path, cd):
        if cache_leaf_kind(cache_leaf_name(path)) == "state":
            return cd
        groups = cd.shape[0]
        return CacheDef((groups, num_pages, page_size, hkv, hd),
                        ("layers", "kv_pages", None, "kv_heads", None),
                        cd.dtype)

    return jax.tree_util.tree_map_with_path(
        to_pool, cache_defs(cfg, slots, max_len),
        is_leaf=lambda x: isinstance(x, CacheDef))


class PagedKVCache:
    """Device page pools + page table + host-side free-list allocator.

    The device state (``cache`` pytree, ``page_table``) flows through the
    engine's donated dispatches; this object owns the *allocation* state:
    which physical pages belong to which slot, and which are free.  The
    page table itself is kept as host numpy (tiny) and re-uploaded per
    dispatch — allocation happens between dispatches, never inside jit.
    """

    def __init__(self, cfg: ModelConfig, *, slots: int, max_len: int,
                 page_size: int = 16, mesh=None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.page_size = min(page_size, max_len)
        self.pages_per_slot = cdiv(max_len, self.page_size)
        self.num_pages = 1 + slots * self.pages_per_slot
        self._defs = paged_cache_defs(cfg, slots, max_len, self.page_size)
        # Mesh-aware pool layout (DESIGN.md §9): K/V pools shard over the
        # model axis at their ``kv_heads`` dim — resolved through the same
        # logical-axis rules as the parameters, so a head count that does
        # not divide falls back to replication.  The page table (and the
        # slot-contiguous state leaves) stay replicated: every shard
        # resolves the same logical->physical page indirection and only
        # streams its own heads' slice of each page.
        self.mesh = mesh
        self.kv_shards = 1
        self._shardings: Optional[Tree] = None
        if mesh is not None:
            from ..distributed.sharding import spec_for

            def leaf_sharding(path, cd):
                if cache_leaf_kind(cache_leaf_name(path)) != "kv":
                    return NamedSharding(mesh, P())
                return NamedSharding(
                    mesh, spec_for(cfg, cd.axes, cd.shape, mesh))

            self._shardings = jax.tree_util.tree_map_with_path(
                leaf_sharding, self._defs,
                is_leaf=lambda x: isinstance(x, CacheDef))
            def claims_model(spec) -> bool:
                return any(e == "model"
                           or (isinstance(e, tuple) and "model" in e)
                           for e in spec)

            for s in jax.tree.leaves(
                    self._shardings,
                    is_leaf=lambda x: isinstance(x, NamedSharding)):
                if claims_model(s.spec):
                    self.kv_shards = int(mesh.shape["model"])
                    break
        # Bytes of ONE physical page summed over every K/V pool leaf (all
        # layer groups) — the unit of the bytes-in-use accounting.
        self.page_bytes = 0
        for path, cd in jax.tree_util.tree_flatten_with_path(
                self._defs, is_leaf=lambda x: isinstance(x, CacheDef))[0]:
            if cache_leaf_kind(cache_leaf_name(path)) == "kv":
                g, _, ps, h, hd = cd.shape
                self.page_bytes += (g * ps * h * hd
                                    * jnp.dtype(cd.dtype).itemsize)
        self._table = np.zeros((slots, self.pages_per_slot), np.int32)
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self.peak_pages = 0

    def init_cache(self) -> Tree:
        """Fresh device cache tree (paged pools + slot-contiguous state).
        The engine owns it from here: it is donated through every dispatch
        and this object only tracks which pages are whose.  With a mesh,
        every leaf is placed under its ``NamedSharding`` (K/V pools
        ``kv_heads``-sharded over 'model', the rest replicated)."""
        if self._shardings is None:
            return jax.tree.map(
                lambda cd: jnp.zeros(cd.shape, cd.dtype), self._defs,
                is_leaf=lambda x: isinstance(x, CacheDef))
        return jax.tree.map(
            lambda cd, ns: jax.device_put(jnp.zeros(cd.shape, cd.dtype), ns),
            self._defs, self._shardings,
            is_leaf=lambda x: isinstance(x, CacheDef))

    # ------------------------------------------------------------ state
    @property
    def page_table(self) -> jax.Array:
        t = jnp.asarray(self._table)
        if self.mesh is not None:
            t = jax.device_put(t, NamedSharding(self.mesh, P(None, None)))
        return t

    @property
    def pages_in_use(self) -> int:
        return sum(len(o) for o in self._owned)

    @property
    def bytes_in_use(self) -> int:
        return self.pages_in_use * self.page_bytes

    @property
    def peak_bytes_in_use(self) -> int:
        return self.peak_pages * self.page_bytes

    @property
    def peak_bytes_per_shard(self) -> int:
        """Per-device peak K/V bytes: the pools split over ``kv_shards``
        (the 'model' factor the kv_heads dim actually claimed)."""
        return self.peak_bytes_in_use // self.kv_shards

    def slot_pages(self, slot: int) -> np.ndarray:
        return np.asarray(self._owned[slot], np.int32)

    def table_row(self, slot: int) -> np.ndarray:
        """One slot's logical->physical page map (unallocated: NULL)."""
        return self._table[slot].copy()

    @property
    def extent(self) -> int:
        """Positions addressable through the table (>= max_len; a write at
        or past this routes to the NULL page in ``paged_append``)."""
        return self.pages_per_slot * self.page_size

    # ------------------------------------------------------- allocation
    def ensure(self, slot: int, length: int) -> np.ndarray:
        """Allocate pages so ``slot`` can hold ``length`` tokens; returns
        the slot's physical pages.  ``length`` beyond ``max_len`` raises —
        the pool is sized for ``slots * max_len`` exactly, so with that
        contract enforced the free list cannot run dry (the RuntimeError
        below is an internal-invariant guard, not an expected error)."""
        if length > self.max_len:
            raise ValueError(
                f"cannot ensure {length} tokens: slot capacity is "
                f"max_len={self.max_len}")
        need = cdiv(max(length, 1), self.page_size)
        owned = self._owned[slot]
        while len(owned) < need:
            if not self._free:
                raise RuntimeError(
                    f"KV page pool exhausted ({self.num_pages - 1} pages)")
            page = self._free.pop()
            self._table[slot, len(owned)] = page
            owned.append(page)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return self.slot_pages(slot)

    def release(self, slot: int) -> None:
        """Return a finished slot's pages to the free list and point its
        table row back at the NULL page."""
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self._table[slot, :] = NULL_PAGE
