"""Accuracy gate for quantized serving.

Every ``QuantMode`` is scored against the f32 (``quant="none"``)
reference along the reference's own greedy trajectory (teacher
forcing), so logits are comparable at every step:

* ``max_logit_err`` — max absolute logit difference across the prefill
  read-out and every decode step;
* ``tokens_equal``  — whether the quantized argmax agrees with the
  reference at every step.  Under teacher forcing, per-step argmax
  agreement is exactly greedy-stream equality (by induction the
  trajectories coincide until the first mismatch).

``run_suite`` sweeps the ``configs/`` registry (skipping architectures
the paged engine cannot serve) and is the gate the quant CI job and
``tests/test_quantized.py`` run.  ``python -m repro.serving.accuracy``
prints the table.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, ModelConfig, get_config
from ..models import init_params
from ..models.model import decode_step, prefill_chunk
from .kv_cache import PagedKVCache, stage_chunk

QUANT_MODES: Tuple[str, ...] = ("kv_int8", "kv_fp8", "w8", "w8_kv8")


def jitter_params(params, seed: int = 0, sigma: float = 0.05):
    """Add small Gaussian noise to every float leaf.

    ``init_params`` zero-initialises norm scales, which under the raw
    ``layer_norm`` convention makes layernorm configs (gpt2) emit
    identically-zero logits — any parity check on them would pass
    vacuously.  Jittered parameters make the accuracy gate real for
    every architecture.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    out = []
    for i, leaf in enumerate(leaves):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            noise = jax.random.normal(jax.random.fold_in(key, i),
                                      leaf.shape, jnp.float32)
            leaf = (leaf.astype(jnp.float32)
                    + sigma * noise).astype(leaf.dtype)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def supports_quantized_serving(cfg: ModelConfig) -> bool:
    """Paged KV quantization needs a decoder with attention KV pages."""
    return (not cfg.encoder_only) and (not cfg.rwkv) and cfg.num_kv_heads > 0


def _greedy_rollout(params, cfg: ModelConfig, kv: PagedKVCache,
                    prompt: np.ndarray, steps: int,
                    forced: Optional[List[int]] = None,
                    ) -> Tuple[List[int], List[np.ndarray]]:
    """Prefill ``prompt`` into slot 0 then decode ``steps`` tokens.

    When ``forced`` is given the input token at each decode step comes
    from it (teacher forcing); the returned token list is still the
    model's own argmax at each step.  Returns (argmax tokens, logits
    per step) where entry 0 is the prefill read-out.
    """
    cache = kv.init_cache()
    plen = len(prompt)
    kv.ensure(0, plen + steps + 1)
    row = kv.table_row(0)
    chunk = max(kv.page_size, 1 << (plen - 1).bit_length())
    toks, cpages, last = stage_chunk(prompt, 0, chunk, row, kv.page_size)
    _, logits, cache = prefill_chunk(
        params, cfg, jnp.asarray(toks)[None], cache, jnp.asarray(row),
        jnp.asarray(cpages), jnp.int32(0), jnp.int32(last))
    steps_logits = [np.asarray(logits, np.float32).reshape(-1)]
    out = [int(steps_logits[0].argmax())]
    table = kv.page_table
    for i in range(steps - 1):
        tok = forced[i] if forced is not None else out[-1]
        pos = jnp.asarray([plen + i], jnp.int32)
        _, logits, cache = decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), cache, pos, pos,
            page_table=table)
        steps_logits.append(np.asarray(logits, np.float32).reshape(-1))
        out.append(int(steps_logits[-1].argmax()))
    return out, steps_logits


def run_accuracy(cfg_or_arch, modes: Iterable[str] = QUANT_MODES, *,
                 prompt_len: int = 16, steps: int = 8, seed: int = 0,
                 page_size: int = 8, fused: Optional[bool] = None,
                 ) -> Dict[str, Dict[str, object]]:
    """Score ``modes`` against the quant="none" reference for one config.

    Returns ``{mode: {"max_logit_err", "tokens_equal", "kv_itemsize",
    "tokens"}}`` plus a ``"none"`` entry holding the reference tokens.
    """
    cfg = get_config(cfg_or_arch).reduced() \
        if isinstance(cfg_or_arch, str) else cfg_or_arch
    if not supports_quantized_serving(cfg):
        raise ValueError(f"{cfg.name} cannot serve quantized KV pages")
    cfg = dataclasses.replace(cfg, dtype="float32")
    if fused is not None:
        cfg = dataclasses.replace(cfg, use_fused_kernels=fused)
    params = jitter_params(init_params(jax.random.PRNGKey(seed), cfg),
                           seed=seed)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab_size, prompt_len).astype(np.int32)
    max_len = prompt_len + steps + page_size

    def fresh_kv(c):
        return PagedKVCache(c, slots=1, max_len=max_len, page_size=page_size)

    ref_cfg = dataclasses.replace(cfg, quant="none")
    ref_tokens, ref_logits = _greedy_rollout(
        params, ref_cfg, fresh_kv(ref_cfg), prompt, steps)
    report: Dict[str, Dict[str, object]] = {
        "none": {"tokens": ref_tokens, "max_logit_err": 0.0,
                 "tokens_equal": True, "kv_itemsize": 4.0},
    }
    for mode in modes:
        qcfg = dataclasses.replace(cfg, quant=mode)
        qkv = fresh_kv(qcfg)
        q_tokens, q_logits = _greedy_rollout(
            params, qcfg, qkv, prompt, steps, forced=ref_tokens[:-1])
        err = max(float(np.abs(a - b).max())
                  for a, b in zip(ref_logits, q_logits))
        report[mode] = {
            "max_logit_err": err,
            "tokens_equal": q_tokens == ref_tokens,
            "kv_itemsize": float(qkv.kv_itemsize_effective),
            "tokens": q_tokens,
        }
    return report


def run_suite(archs: Optional[Iterable[str]] = None,
              modes: Iterable[str] = QUANT_MODES, **kw,
              ) -> Dict[str, Dict[str, Dict[str, object]]]:
    """Accuracy reports for every (servable) arch in the registry."""
    if archs is None:
        archs = [a for a in ARCHS
                 if supports_quantized_serving(ARCHS[a])]
    return {a: run_accuracy(a, modes, **kw) for a in archs}


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch ids (default: all servable)")
    ap.add_argument("--modes", default=",".join(QUANT_MODES))
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--fused", action="store_true")
    args = ap.parse_args(argv)
    archs = args.archs.split(",") if args.archs else None
    suite = run_suite(archs, modes=args.modes.split(","), steps=args.steps,
                      fused=True if args.fused else None)
    bad = 0
    for arch, rep in suite.items():
        for mode, r in rep.items():
            if mode == "none":
                continue
            flag = "OK " if r["tokens_equal"] else "DIV"
            bad += not r["tokens_equal"] and mode in ("kv_int8", "w8_kv8") \
                and arch in ("gpt2", "llama3-8b")
            print(f"{arch:>22s} {mode:>8s}  {flag}  "
                  f"max|dlogit|={r['max_logit_err']:.4g}  "
                  f"itemsize={r['kv_itemsize']:.3f}B")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
