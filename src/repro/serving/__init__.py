"""Serving runtime: continuous-batching engine over a paged KV cache
with radix-tree prefix sharing."""
from .accuracy import QUANT_MODES, jitter_params, run_accuracy, run_suite
from .engine import Request, ServingEngine
from .kv_cache import (PagedKVCache, cow_copy_pool, gather_pages,
                       gather_pages_dequant, paged_append, paged_append_q,
                       place_chunk_pages, place_chunk_pages_q,
                       place_prefill, quantize_kv)
from .prefix_cache import PrefixCache, PrefixHit
__all__ = ["Request", "ServingEngine", "PagedKVCache", "PrefixCache",
           "PrefixHit", "QUANT_MODES", "cow_copy_pool", "gather_pages",
           "gather_pages_dequant", "jitter_params", "paged_append",
           "paged_append_q", "place_chunk_pages", "place_chunk_pages_q",
           "place_prefill", "quantize_kv", "run_accuracy", "run_suite"]
