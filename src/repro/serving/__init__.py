"""Serving runtime: continuous-batching engine over a paged KV cache
with radix-tree prefix sharing."""
from .engine import Request, ServingEngine
from .kv_cache import (PagedKVCache, cow_copy_pool, gather_pages,
                       paged_append, place_chunk_pages, place_prefill)
from .prefix_cache import PrefixCache, PrefixHit
__all__ = ["Request", "ServingEngine", "PagedKVCache", "PrefixCache",
           "PrefixHit", "cow_copy_pool", "gather_pages", "paged_append",
           "place_chunk_pages", "place_prefill"]
