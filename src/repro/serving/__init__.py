"""Serving runtime: batched prefill/decode engine."""
from .engine import Request, ServingEngine
__all__ = ["Request", "ServingEngine"]
