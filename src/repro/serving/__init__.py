"""Serving runtime: continuous-batching engine over a paged KV cache."""
from .engine import Request, ServingEngine
from .kv_cache import (PagedKVCache, gather_pages, paged_append,
                       place_chunk_pages, place_prefill)
__all__ = ["Request", "ServingEngine", "PagedKVCache", "gather_pages",
           "paged_append", "place_chunk_pages", "place_prefill"]
