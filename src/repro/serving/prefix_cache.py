"""Prefix cache: radix-tree page sharing for the paged KV pools.

StreamTensor's thesis is that external-memory traffic is the bottleneck;
the serving corollary is that KV for a shared prompt prefix should be
computed and stored ONCE.  The paged cache (DESIGN.md §8a) already makes
the page the unit of ownership, so sharing is a bookkeeping problem, not
a data-movement one: this module maintains a token-keyed radix tree over
*whole KV pages* — node key = one page-aligned chunk of token ids,
payload = the physical page holding that chunk's K/V — and a newly
admitted request walks the tree, claims every matching prefix page by
bumping its refcount and writing the shared physical id straight into
its page-table row, then runs chunked prefill only from the first
non-cached page onward.  TTFT for a hot prefix drops to roughly the cost
of the divergent tail.

Sharing is exact for every attention variant served here (dense, GQA,
sliding-window): a KV row at position ``p`` is a pure function of tokens
``0..p`` and the absolute position, and a claimed page sits at the SAME
logical positions in the claiming slot, so the gathered values are the
values a cold prefill would have produced.

Two matching granularities:

  * **chunk-aligned** (default, bit-exact) — the prefill restart offset
    is rounded DOWN to the engine's chunk grid and only pages below it
    are claimed.  Every page in the tree was then computed by the one
    compiled ``prefill_chunk`` program at a canonical grid offset, so a
    hot request's outputs are bit-identical to its cold-start run (chunk
    boundaries change floating-point summation order; keeping one grid
    keeps one answer).
  * **bootstrap** (``bootstrap=True``, page-granular) — claims every
    matching page, plus the *partial tail page* when a prompt ends
    mid-page inside a cached run.  A prompt whose cached coverage
    reaches ``plen - 1`` tokens skips prefill entirely: the engine feeds
    the final prompt token through the decode path, whose first append
    lands inside the shared last page and triggers the copy-on-write
    swap (``kv_cache.cow_page`` + the in-dispatch page copy).  Maximum
    reuse, one-decode-step TTFT, at the cost of ulp-level (not
    token-level, in practice) divergence from the cold trace.

Custody and eviction: a slot's full prompt pages are inserted into the
tree when its prefill completes (``mark_tree``), so concurrent requests
share with still-active ones.  On slot exit the references drop but the
pages STAY CACHED at refcount zero; when the allocator's free list runs
dry it calls ``evict_lru_leaf`` (wired via ``PagedKVCache.evictor``),
which reclaims the least-recently-stamped unreferenced leaf through a
stamp-keyed LRU heap (no tree walk on the allocation path).  Because
``extend_claim`` lets a same-wave request adopt only a *suffix* of a
chain, an unreferenced ancestor can sit above referenced descendants;
when no unreferenced leaf exists, eviction prunes the LRU unreferenced
subtree instead — cached pages free, still-referenced pages just lose
tree custody and free when their slots exit — so eviction always makes
progress while any tree page is unreferenced.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..obs import (NULL_RECORDER, PREFIX_CLAIM, PREFIX_EVICT,
                   PREFIX_INSERT, TRACK_PREFIX)
from .kv_cache import NULL_PAGE, PagedKVCache, cdiv


class _Node:
    """One radix-tree node: a page-aligned token chunk -> physical page."""

    __slots__ = ("key", "page", "children", "parent", "stamp", "dead")

    def __init__(self, key: Optional[Tuple[int, ...]], page: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.stamp = 0
        self.dead = False


@dataclass
class PrefixHit:
    """Outcome of one admission walk (already applied to the allocator).

    ``prefill_start`` is the page-aligned token offset chunked prefill
    resumes at; ``hit_pages`` the pages claimed (KV reused verbatim);
    ``cow`` is the LOGICAL page index whose first divergent write must
    swap in a private copy first (the physical src is whatever the
    slot's table row holds when the write happens — ``cow_page``
    re-derives it, so no stale copy of allocator state rides along);
    ``full`` marks a bootstrap-mode full hit (cached coverage >=
    plen - 1: skip prefill, emit the first token through the decode
    path)."""

    prefill_start: int
    hit_pages: int
    prompt_pages: int
    cow: Optional[int] = None
    full: bool = False


class PrefixCache:
    """Radix tree over whole KV pages + LRU eviction + claim bookkeeping.

    Owns the token->page index and the per-slot list of held nodes; all
    refcount/free-list state lives in the ``PagedKVCache`` it wraps (the
    tree registers itself as the allocator's ``evictor``)."""

    def __init__(self, kv: PagedKVCache, *, chunk: Optional[int] = None,
                 bootstrap: bool = False, obs=NULL_RECORDER):
        if chunk is None:
            chunk = kv.page_size
        if chunk % kv.page_size != 0:
            raise ValueError(
                f"chunk {chunk} is not a multiple of page_size "
                f"{kv.page_size}")
        self.kv = kv
        # Telemetry recorder: claim/insert/evict instants on "prefix".
        self.obs = obs
        self.chunk = chunk
        self.bootstrap = bootstrap
        self.root = _Node(None, NULL_PAGE, None)
        self._held: List[Set[_Node]] = [set() for _ in range(kv.slots)]
        # Eviction index: a stamp-keyed min-heap with lazy invalidation
        # (every _stamp pushes; pops skip dead/stale entries), plus a
        # physical-page -> node map so a refcount drop outside the
        # release path (COW) can refresh the node's heap entry.
        self._lru: List[Tuple[int, int, _Node]] = []
        self._by_page: Dict[int, _Node] = {}
        self._tick = 0
        self.nodes = 0
        self.evictions = 0
        kv.evictor = self.evict_lru_leaf

    # ------------------------------------------------------------- walk
    def _key(self, prompt: np.ndarray, i: int) -> Tuple[int, ...]:
        ps = self.kv.page_size
        return tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])

    def _walk(self, prompt: np.ndarray) -> List[_Node]:
        """Match the prompt's full page chunks from the root; returns the
        (possibly empty) chain of matching nodes."""
        node, out = self.root, []
        for i in range(int(prompt.shape[0]) // self.kv.page_size):
            child = node.children.get(self._key(prompt, i))
            if child is None:
                break
            out.append(child)
            node = child
        return out

    def lookup_pages(self, prompt: np.ndarray) -> int:
        """Pages a claim would reuse — the ``admission="prefix"`` score.
        Pure lookup: no refcounts move."""
        return len(self._walk(prompt))

    # ---------------------------------------------------------- drafting
    def suggest(self, tokens: np.ndarray, k: int) -> List[int]:
        """Draft lookup for speculative decoding: up to ``k`` token ids a
        cached run continued with after ``tokens``.  Pure — no refcounts
        move, no pages are claimed, and the nodes are not re-stamped
        (drafting must not perturb LRU eviction order: a wrong guess
        costs one rejected row, it shouldn't also pin the page).

        The radix tree doubles as a draft table: its keys ARE token
        history.  The walk matches ``tokens``'s full page chunks, then
        matches the partial remainder against a child key's prefix and
        emits that key's continuation; from there it keeps descending,
        preferring the most-recently-stamped child at each fork (the
        hottest cached continuation).  Returns [] when the history
        diverges from everything cached — the engine falls back to
        n-gram prompt lookup.
        """
        if k <= 0:
            return []
        ps = self.kv.page_size
        n = int(tokens.shape[0])
        node = self.root
        for i in range(n // ps):
            child = node.children.get(self._key(tokens, i))
            if child is None or child.dead:
                return []
            node = child
        out: List[int] = []
        r = n % ps
        if r:
            tail = tuple(int(t) for t in tokens[n - r:])
            nxt = None
            for key, child in node.children.items():
                if child.dead or key[:r] != tail:
                    continue
                if nxt is None or child.stamp > nxt.stamp:
                    nxt = child
            if nxt is None:
                return []
            out.extend(nxt.key[r:])
            node = nxt
        while len(out) < k and node.children:
            nxt = max((c for c in node.children.values() if not c.dead),
                      key=lambda c: c.stamp, default=None)
            if nxt is None:
                break
            out.extend(nxt.key)
            node = nxt
        return out[:k]

    # ------------------------------------------------------------ claim
    def claim(self, slot: int, prompt: np.ndarray) -> PrefixHit:
        """Admission-time prefix walk: claim matching pages into the
        slot's table row (refcount bumps via ``adopt_shared``) and decide
        where prefill resumes.  See the module docstring for the two
        granularities."""
        ps = self.kv.page_size
        plen = int(prompt.shape[0])
        full, r = plen // ps, plen % ps
        matched = self._walk(prompt)
        m = len(matched)
        cow: Optional[int] = None
        full_hit = False

        if self.bootstrap:
            claim_nodes = list(matched)
            covered = m * ps
            if m == full and r > 0:
                # Partial-last-page: a cached run that extends past this
                # prompt holds its tail rows — claim that child page too
                # when its first ``r`` tokens match.
                tail = tuple(int(t) for t in prompt[full * ps:plen])
                parent = matched[-1] if matched else self.root
                for key, child in parent.children.items():
                    if key[:r] == tail:
                        claim_nodes.append(child)
                        covered = plen
                        break
            full_hit = bool(claim_nodes) and covered >= plen - 1
            prefill_start = plen if full_hit else m * ps
            if full_hit:
                # Decode's first append (position plen - 1) — does it
                # land inside a claimed page?  (At r == 1 the write opens
                # a fresh page: no copy needed.)
                j = (plen - 1) // ps
                if j < len(claim_nodes):
                    cow = j
        else:
            # Bit-exact: restart on the chunk grid so every page the
            # request computes (and later inserts) comes from the one
            # canonical chunk schedule; claim only pages below it.
            cs = (min(m * ps, plen - 1) // self.chunk) * self.chunk
            claim_nodes = matched[: cs // ps]
            prefill_start = cs

        for node in claim_nodes:
            self.kv.adopt_shared(slot, node.page)
            self._stamp(node)
            self._held[slot].add(node)
        if self.obs.enabled:
            self.obs.instant(PREFIX_CLAIM, track=TRACK_PREFIX, slot=slot,
                             hit_pages=len(claim_nodes),
                             prefill_start=prefill_start, full=full_hit)
        return PrefixHit(prefill_start=prefill_start,
                         hit_pages=len(claim_nodes),
                         prompt_pages=cdiv(plen, ps), cow=cow,
                         full=full_hit)

    def extend_claim(self, slot: int, prompt: np.ndarray,
                     off: int) -> Tuple[int, int]:
        """Mid-prefill catch-up walk: a request admitted alongside the
        one that is COMPUTING its prefix sees the tree only fill up
        after its own prefill started.  Called before each chunk
        dispatch, this re-walks the tree and — when pages covering
        chunks at/after ``off`` have appeared — claims them and jumps
        the prefill offset past them (chunk-aligned, capped at
        ``plen - 1`` so the final chunk still runs for logits).  Returns
        ``(new_off, pages_claimed)``; ``(off, 0)`` when nothing new
        matched."""
        ps = self.kv.page_size
        plen = int(prompt.shape[0])
        if self.kv.slot_pages(slot).size * ps != off:
            return off, 0            # mid-page/COW state: don't touch
        matched = self._walk(prompt)
        cs = (min(len(matched) * ps, plen - 1) // self.chunk) * self.chunk
        if cs <= off:
            return off, 0
        claimed = 0
        for j in range(off // ps, cs // ps):
            node = matched[j]
            self.kv.adopt_shared(slot, node.page)
            self._stamp(node)
            self._held[slot].add(node)
            claimed += 1
        return cs, claimed

    # ----------------------------------------------------------- insert
    def insert(self, slot: int, prompt: np.ndarray) -> int:
        """Index the slot's full prompt pages when its prefill completes
        (their contents are final from here on — decode appends strictly
        past the prompt).  Already-claimed chunks are just re-stamped; a
        chunk that raced a concurrent cold admission keeps the FIRST
        inserted page (this slot's duplicate stays exclusive and frees
        normally on exit).  Returns the number of nodes created."""
        ps = self.kv.page_size
        full = int(prompt.shape[0]) // ps
        row = self.kv.slot_pages(slot)
        node, created = self.root, 0
        for i in range(full):
            key = self._key(prompt, i)
            child = node.children.get(key)
            if child is None:
                page = int(row[i])
                child = _Node(key, page, node)
                node.children[key] = child
                self.kv.mark_tree(page)
                self._by_page[page] = child
                self.nodes += 1
                created += 1
            self._stamp(child)
            if child.page == row[i]:
                self._held[slot].add(child)
            node = child
        if self.obs.enabled:
            self.obs.instant(PREFIX_INSERT, track=TRACK_PREFIX, slot=slot,
                             created=created, pages=full)
        return created

    # ---------------------------------------------------------- custody
    def release_slot(self, slot: int) -> None:
        """Slot exit: re-stamp the nodes it held (most-recently-used at
        exit, so hot prefixes outlive cold ones — and the fresh heap
        entries are what makes their now-unreferenced pages reachable by
        eviction) and forget them.  The refcount drops happen in
        ``PagedKVCache.release``; tree-owned pages stay cached there
        until eviction."""
        for node in self._held[slot]:
            self._stamp(node)
        self._held[slot] = set()

    def page_released(self, page: int) -> None:
        """A page reference dropped OUTSIDE the release path (the COW
        swap moves a slot's reference off its shared src page): refresh
        the node's heap entry so the now-maybe-unreferenced page stays
        reachable by eviction."""
        node = self._by_page.get(page)
        if node is not None and not node.dead:
            self._stamp(node)

    def evict_lru_leaf(self) -> bool:
        """Reclaim the least-recently-stamped unreferenced page.

        Normal case: pop the LRU heap until a live, unreferenced LEAF
        surfaces and evict it — amortized O(log n), no tree walk (every
        path to refcount zero re-stamps the node, so an evictable page
        always has a current heap entry).  Referenced entries are
        dropped (their release will re-push); unreferenced INTERIOR
        entries are kept aside and re-pushed.  When no unreferenced
        leaf exists at all — possible since ``extend_claim`` lets a
        request adopt only a SUFFIX of a chain, leaving unreferenced
        ancestors above referenced descendants — the LRU unreferenced
        interior node's whole subtree is pruned instead: its cached
        pages free, its still-referenced pages merely lose tree custody
        (``disown``) and return to the free list when their slots exit.
        Returns False only when no tree page is unreferenced."""
        repush: List[Tuple[int, int, _Node]] = []
        best_interior: Optional[_Node] = None
        victim: Optional[_Node] = None
        while self._lru:
            entry = heapq.heappop(self._lru)
            stamp, _, node = entry
            if node.dead or stamp != node.stamp:
                continue                         # stale entry
            if self.kv.page_refs(node.page) != 0:
                continue                         # re-pushed on release
            repush.append(entry)
            if node.children:
                if best_interior is None:
                    best_interior = node         # LRU prune fallback
                continue
            victim = node
            break
        for entry in repush:
            heapq.heappush(self._lru, entry)
        if victim is None:
            victim = best_interior
        if victim is None:
            return False
        return self._prune(victim) > 0

    def _prune(self, node: _Node) -> int:
        """Detach ``node``'s subtree from the tree.  Unreferenced pages
        are reclaimed; referenced ones are disowned (no longer shareable
        — the walk can't reach them — but still valid for their slots).
        Returns the number of pages freed."""
        del node.parent.children[node.key]
        freed = 0
        stack = [node]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            n.dead = True
            self._by_page.pop(n.page, None)
            for held in self._held:
                held.discard(n)
            if self.kv.page_refs(n.page) == 0:
                self.kv.evict_page(n.page)
                freed += 1
            else:
                self.kv.disown(n.page)
            self.nodes -= 1
        self.evictions += freed
        if self.obs.enabled:
            self.obs.instant(PREFIX_EVICT, track=TRACK_PREFIX,
                             freed=freed, nodes=self.nodes)
        return freed

    def _stamp(self, node: _Node) -> None:
        self._tick += 1
        node.stamp = self._tick
        heapq.heappush(self._lru, (node.stamp, id(node), node))

    # ------------------------------------------------------------ state
    @property
    def cached_pages(self) -> int:
        return self.kv.pages_cached
