"""Fig. 9 reproduction: energy efficiency (tokens/J) vs A100.

tokens/J = decode speed / power.  Ours: calibrated U55C model at 150 W
design power; A100 measured speeds (Table 5 / paper Fig. 9 context) at
300 W.  The paper reports 1.99x (Qwen) and 1.59x (Gemma) advantages.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import PAPER_MODELS

from .fpga_model import calibrated_latency
from .paper_data import FIG9_RATIO_GEMMA, FIG9_RATIO_QWEN, POWER

# A100 decode speeds for the emerging models (tok/s) — derived from the
# paper's Fig. 9 bar ratios and its GPT-2 measurements.
A100_SPEED = {"gpt2": 115.0, "paper-qwen": 90.0, "paper-llama": 70.0,
              "paper-gemma": 85.0}


def run() -> List[Dict[str, float]]:
    rows = []
    for name, cfg in PAPER_MODELS.items():
        ours = calibrated_latency(cfg, 128)
        speed = ours.speed_tps(128)
        ours_tpj = speed / POWER["ours"]
        a100_tpj = A100_SPEED[name] / POWER["a100"]
        rows.append({"model": name, "ours_tps": speed,
                     "ours_tokens_per_J": ours_tpj,
                     "a100_tokens_per_J": a100_tpj,
                     "ratio": ours_tpj / a100_tpj})
    return rows


def main() -> None:
    print("# Fig. 9 — energy efficiency (tokens/J)")
    for r in run():
        print(f"{r['model']:16s} ours={r['ours_tokens_per_J']:.2f} tok/J "
              f"a100={r['a100_tokens_per_J']:.2f} tok/J "
              f"ratio={r['ratio']:.2f}")
    print(f"paper ratios: qwen {FIG9_RATIO_QWEN}, gemma {FIG9_RATIO_GEMMA}")


if __name__ == "__main__":
    main()
