"""U55C dataflow latency model for the paper-reproduction benchmarks.

Combines the StreamTensor compiler's own dataflow makespan (token behavior
model + LP start times, §5.3) with two platform calibration constants fitted
once against the paper's measured GPT-2 [32:32] and [256:256] rows:

  * ``LAYER_OVERHEAD_S``  — per-layer accelerator invocation overhead
    (Vitis kernel launch + DMA descriptor setup).  The paper executes one
    fused transformer block per FPGA and re-triggers it per layer (§6.1).
  * ``GENERATION_FIXED_S`` — per-generation fixed cost (cache install).

Everything else is first-principles: weight streaming at HBM bandwidth
(W4A8), kernel (L, D, II) from the platform model, LP-scheduled overlap.
The validation (table4 benchmark) checks the *other* rows and the TTFT
scaling the paper highlights.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, Tuple

from repro.configs.base import ModelConfig
from repro.core.dse import evaluate_trial, modeled_latency_s
from repro.core.platforms import U55C, Platform
from repro.core.trace import trace_block

# Calibrated against paper Table 4 GPT-2 [32:32] & [256:256] (see module
# docstring); typical Vitis invocation overhead is O(100us), matching.
LAYER_OVERHEAD_S = 160e-6
GENERATION_FIXED_S = 24.2e-3
W4_BYTES_PER_PARAM = 0.5

# --- calibrated per-token constants (fit: TTFT on [32:32]; decode on
# [32:32]+[256:256]; rows [64:64]/[128:128] are HELD OUT and used as the
# validation in table4) -------------------------------------------------
II_PROMPT_S = 45.0e-6        # per token per layer, prompt streaming
DECODE_TOKEN_S = 4.262e-3    # per generated token, whole model


@dataclass(frozen=True)
class LatencyBreakdown:
    ttft_s: float
    per_token_s: float
    fixed_s: float

    def latency_s(self, out_len: int) -> float:
        return self.ttft_s + self.fixed_s + out_len * self.per_token_s

    def speed_tps(self, out_len: int) -> float:
        """Paper metric: out_len / (latency - TTFT)."""
        return out_len / (self.fixed_s + out_len * self.per_token_s)


@lru_cache(maxsize=None)
def _block_makespan_s(cfg: ModelConfig, tokens: int, kv_len: int,
                      platform: Platform = U55C) -> float:
    ops = trace_block(cfg, tokens=tokens, kv_len=kv_len)
    trial = evaluate_trial(ops, platform, 64, 64, keep_artifacts=True)
    # Dataflow makespan only (weight DMA charged separately below so the
    # whole-model weight stream isn't double counted per block).
    makespan_cycles = max(
        trial.fifo.start_times[k.name] + k.timing.latency
        for k in trial.graph.kernels())
    return platform.seconds(makespan_cycles)


def weight_stream_s(cfg: ModelConfig, platform: Platform = U55C) -> float:
    """One full pass of W4 weights from HBM (decode reads every weight)."""
    return cfg.param_count() * W4_BYTES_PER_PARAM / platform.hbm_bw


def model_latency(cfg: ModelConfig, in_len: int,
                  platform: Platform = U55C) -> LatencyBreakdown:
    """First-principles compiler model: LP-scheduled block makespans +
    weight streaming + invocation overheads.  Reported alongside the
    calibrated model; its known gap (weight-stream-bound blocks make TTFT
    flat where the paper's measured design is per-token-II-bound) is
    discussed in EXPERIMENTS.md."""
    layers = cfg.num_layers
    prefill_block = _block_makespan_s(cfg, in_len, in_len, platform)
    ttft = layers * (prefill_block + LAYER_OVERHEAD_S) + \
        weight_stream_s(cfg, platform)
    decode_block = _block_makespan_s(cfg, 1, in_len, platform)
    per_token = layers * (decode_block + LAYER_OVERHEAD_S) + \
        weight_stream_s(cfg, platform)
    return LatencyBreakdown(ttft_s=ttft, per_token_s=per_token,
                            fixed_s=GENERATION_FIXED_S)


def calibrated_latency(cfg: ModelConfig, in_len: int,
                       platform: Platform = U55C) -> LatencyBreakdown:
    """Calibrated U55C model (constants fit on the [32:32] and [256:256]
    GPT-2 rows; middle rows held out).  Per-token terms scale with the
    model's weight volume relative to GPT-2, keeping the decode
    weight-bandwidth-bound structure the paper relies on (§6.1)."""
    gpt2_weights = 353e6 * W4_BYTES_PER_PARAM
    scale = (cfg.param_count() * W4_BYTES_PER_PARAM) / gpt2_weights
    layer_scale = cfg.num_layers / 24.0
    ttft = cfg.num_layers * in_len * II_PROMPT_S
    per_token = DECODE_TOKEN_S * max(scale, layer_scale * 0.5)
    return LatencyBreakdown(ttft_s=ttft, per_token_s=per_token,
                            fixed_s=GENERATION_FIXED_S)


def gpu_roofline_latency(cfg: ModelConfig, in_len: int,
                         platform: Platform) -> LatencyBreakdown:
    """Pure-roofline GPU model (no software overhead): prefill is compute
    bound, decode is weight-bandwidth bound.  The gap between this and the
    paper's measured GPU rows is the framework overhead StreamTensor's
    dataflow execution avoids — reported alongside in table5."""
    n = cfg.param_count()
    flops_prefill = 2.0 * n * in_len
    ttft = max(flops_prefill / platform.peak_int8_ops,
               n / platform.hbm_bw)          # W8A8
    per_token = max(2.0 * n / platform.peak_int8_ops, n / platform.hbm_bw)
    return LatencyBreakdown(ttft_s=ttft, per_token_s=per_token, fixed_s=0.0)
