"""Table 5 reproduction: GPT-2, ours vs A100/2080Ti.

GPU rows are reported three ways: the paper's measured values, our pure
roofline model (no framework overhead), and the implied software-overhead
factor — quantifying the gap StreamTensor's dataflow execution exploits
(the paper's §6.1 argument: decode is memory-bound, GPUs leave the
bandwidth unused at batch 1).
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import get_config
from repro.core.platforms import A100, RTX2080TI

from .fpga_model import calibrated_latency, gpu_roofline_latency
from .paper_data import TABLE5_2080TI, TABLE5_A100


def run() -> List[Dict[str, float]]:
    cfg = get_config("gpt2")
    rows = []
    for (i, o), (lat_a, ttft_a, spd_a) in TABLE5_A100.items():
        ours = calibrated_latency(cfg, i)
        lat = ours.latency_s(o) * 1e3
        roof_a = gpu_roofline_latency(cfg, i, A100)
        roof_t = gpu_roofline_latency(cfg, i, RTX2080TI)
        lat_t, ttft_t, spd_t = TABLE5_2080TI[(i, o)]
        rows.append({
            "in": i, "out": o, "ours_ms": lat,
            "a100_ms": lat_a, "ratio_a100": lat / lat_a,
            "2080ti_ms": lat_t, "ratio_2080ti": lat / lat_t,
            "a100_roofline_ms": roof_a.latency_s(o) * 1e3,
            "a100_sw_overhead": lat_a / (roof_a.latency_s(o) * 1e3),
            "ttft_ratio_a100": (ours.ttft_s * 1e3) / ttft_a,
        })
    return rows


def main() -> None:
    rows = run()
    print("# Table 5 — GPT-2 vs GPUs (ours modeled; GPU measured + roofline)")
    print(f"{'in:out':>8s} {'ours_ms':>8s} {'A100':>8s} {'ratio':>6s} "
          f"{'2080Ti':>8s} {'ratio':>6s} {'A100roof':>9s} {'sw_ovh':>7s}")
    for r in rows:
        print(f"{r['in']:>4d}:{r['out']:<3d} {r['ours_ms']:8.1f} "
              f"{r['a100_ms']:8.1f} {r['ratio_a100']:6.2f} "
              f"{r['2080ti_ms']:8.1f} {r['ratio_2080ti']:6.2f} "
              f"{r['a100_roofline_ms']:9.2f} {r['a100_sw_overhead']:7.0f}x")
    import numpy as np
    geo = float(np.exp(np.mean([np.log(r["ratio_a100"]) for r in rows])))
    print(f"geomean latency ratio vs A100: {geo:.2f} (paper: 0.64)")


if __name__ == "__main__":
    main()
