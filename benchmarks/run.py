"""Benchmark aggregator: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name1,name2]

Prints a ``name,seconds,status`` CSV per benchmark plus the human tables.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (fifo_sizing_bench, fig9_energy, fig10a_memory, fig10c_compile,
               roofline_table, table4_gpt2, table5_gpu)

BENCHES = [
    ("table4_gpt2", table4_gpt2.main),
    ("table5_gpu", table5_gpu.main),
    ("fig9_energy", fig9_energy.main),
    ("fig10a_memory", fig10a_memory.main),
    ("fig10c_compile", fig10c_compile.main),
    ("fifo_sizing", fifo_sizing_bench.main),
    ("roofline_table", roofline_table.main),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    failures = []
    lines = []
    for name, fn in BENCHES:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        print(f"\n===== {name} =====", flush=True)
        try:
            fn()
            status = "ok"
        except Exception as e:
            traceback.print_exc()
            failures.append(name)
            status = f"FAILED: {e!r}"
        lines.append(f"{name},{time.perf_counter()-t0:.2f},{status}")
    print("\n# summary CSV")
    print("benchmark,seconds,status")
    for line in lines:
        print(line)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
