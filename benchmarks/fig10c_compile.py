"""Fig. 10b/c reproduction (REAL): compiler stage wall-clock breakdown.

Times every StreamTensor stage (trace, DSE+fusion+FIFO sizing, partition,
allocation, lowering) for each paper model.  The paper's total compile time
(its high-level stages) ranges 26.8-63.4s including MLIR/HLS machinery; our
Python pipeline targets the same asymptotics with small constants.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import PAPER_MODELS
from repro.core.lowering import compile_model
from repro.core.platforms import U55C

from .paper_data import FIG10C_COMPILE_RANGE_S


def run(tokens: int = 256) -> List[Dict[str, float]]:
    rows = []
    for name, cfg in PAPER_MODELS.items():
        t0 = time.perf_counter()
        c = compile_model(cfg, tokens=tokens, platform=U55C, dse_budget=12)
        total = time.perf_counter() - t0
        rows.append({"model": name, "total_s": total,
                     **{f"stage_{k}": v for k, v in c.stage_seconds.items()}})
    return rows


def main() -> None:
    print("# Fig. 10c — compile-time breakdown (s)")
    for r in run():
        stages = {k[6:]: v for k, v in r.items() if k.startswith("stage_")}
        parts = " ".join(f"{k}={v:.2f}" for k, v in stages.items())
        print(f"{r['model']:16s} total={r['total_s']:6.2f}s  {parts}")
    print(f"paper total range: {FIG10C_COMPILE_RANGE_S[0]}-"
          f"{FIG10C_COMPILE_RANGE_S[1]}s (incl. MLIR+profiling machinery)")


if __name__ == "__main__":
    main()
