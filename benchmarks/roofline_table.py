"""§Roofline table generator: aggregates results/dryrun/*.json.

Prints the per-(arch x shape x mesh) three-term roofline table used in
EXPERIMENTS.md, plus dominant bounds and useful-flops ratios.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load() -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(f"{RESULTS}/*.json")):
        r = json.load(open(f))
        if "error" not in r:
            rows.append(r)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute (s) | memory (s) | "
           "collective (s) | bound | useful flops | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl, m = r["roofline"], r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {rl['bound']} "
            f"| {rl['useful_flops_ratio']:.3f} "
            f"| {m['peak_bytes']/2**30:.2f} |")
    return "\n".join(out)


def main() -> None:
    rows = load()
    if not rows:
        print(f"(no dry-run records under {RESULTS}; run "
              f"python -m repro.launch.dryrun --all first)")
        return
    print(markdown_table(rows))
    bounds = {}
    for r in rows:
        bounds[r["roofline"]["bound"]] = \
            bounds.get(r["roofline"]["bound"], 0) + 1
    print(f"\n{len(rows)} cells; dominant bounds: {bounds}")


if __name__ == "__main__":
    main()
