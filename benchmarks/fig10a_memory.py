"""Fig. 10a reproduction (REAL compiler measurement, not a model).

Runs our fusion pass (Algorithms 1+2) on the four paper models' transformer
blocks and reports on-chip intermediate memory after fusion as a fraction
of the unfused design.  Paper band: 14.8%-16.8%.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import PAPER_MODELS
from repro.core.dse import explore
from repro.core.fusion import fusion_memory_report
from repro.core.platforms import U55C
from repro.core.trace import trace_block

from .paper_data import FIG10A_RATIO_BAND


def run(tokens: int = 256) -> List[Dict[str, float]]:
    from repro.core.dse import evaluate_trial
    rows = []
    for name, cfg in PAPER_MODELS.items():
        ops = trace_block(cfg, tokens=tokens)
        # Paper-faithful fixed tiling (default_tile_size applied uniformly).
        fixed = evaluate_trial(ops, U55C, 64, 64, keep_artifacts=True)
        rep_fixed = fusion_memory_report(fixed.graph, fixed.fusion)
        # Our DSE-optimized tiling (beyond-paper: smaller converters).
        res = explore(ops, U55C, budget=12, seed=0)
        rep = fusion_memory_report(res.best.graph, res.best.fusion)
        rows.append({"model": name,
                     "before_mb": rep_fixed["before_bytes"] / 2**20,
                     "after_mb": rep_fixed["after_bytes"] / 2**20,
                     "ratio_fixed": rep_fixed["ratio"],
                     "ratio_dse": rep["ratio"],
                     "groups": res.best.fusion.num_groups})
    return rows


def main() -> None:
    lo, hi = FIG10A_RATIO_BAND
    print("# Fig. 10a — on-chip memory before/after stream fusion")
    print("  (ratio_fixed: uniform default tiling, comparable to the "
          "paper; ratio_dse: tiling-space explorer)")
    for r in run():
        # Success criterion = the paper's qualitative claim: stream fusion
        # removes the large majority of on-chip intermediate memory.
        ok = "OK" if min(r["ratio_fixed"], r["ratio_dse"]) <= 0.30 \
            else "REGRESSION"
        print(f"{r['model']:16s} before={r['before_mb']:8.1f}MB "
              f"after={r['after_mb']:7.2f}MB "
              f"ratio_fixed={r['ratio_fixed']*100:5.1f}% "
              f"ratio_dse={r['ratio_dse']*100:5.1f}% "
              f"[paper {lo*100:.1f}-{hi*100:.1f}%] {ok}")


if __name__ == "__main__":
    main()
