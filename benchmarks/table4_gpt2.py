"""Table 4 reproduction: GPT-2 latency/TTFT/decoding speed on U55C.

Our numbers come from the StreamTensor compiler's own dataflow model
(traced block -> tiling DSE -> fusion -> LP FIFO schedule -> makespan) plus
two calibrated platform constants (see fpga_model.py).  Validation targets:
  * decoding speed within ~15% of every measured row,
  * TTFT linear-in-input-length scaling (the paper's §6.1 claim),
  * latency ratios vs Allo/DFX in the paper's direction (<1).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import get_config

from .fpga_model import calibrated_latency, model_latency
from .paper_data import TABLE4_ALLO, TABLE4_DFX, TABLE4_OURS


def run() -> List[Dict[str, float]]:
    cfg = get_config("gpt2")
    rows = []
    for (i, o), (lat_p, ttft_p, spd_p) in TABLE4_OURS.items():
        cal = calibrated_latency(cfg, i)
        fp = model_latency(cfg, i)
        lat = cal.latency_s(o) * 1e3
        ttft = cal.ttft_s * 1e3
        spd = cal.speed_tps(o)
        rows.append({
            "in": i, "out": o,
            "latency_ms": lat, "ttft_ms": ttft, "speed_tps": spd,
            "fp_latency_ms": fp.latency_s(o) * 1e3,
            "paper_latency_ms": lat_p, "paper_ttft_ms": ttft_p,
            "paper_speed_tps": spd_p,
            "latency_ratio": lat / lat_p,
            "speed_ratio": spd / spd_p,
            "vs_allo": lat / TABLE4_ALLO[(i, o)][0],
            "vs_dfx": lat / TABLE4_DFX[(i, o)][0],
            "held_out": (i, o) in ((64, 64), (128, 128)),
        })
    return rows


def main() -> None:
    rows = run()
    print("# Table 4 — GPT-2 on U55C (ours modeled vs paper measured)")
    print(f"{'in:out':>8s} {'lat_ms':>9s} {'paper':>9s} {'ttft_ms':>8s} "
          f"{'paper':>7s} {'tok/s':>7s} {'paper':>7s} {'vsAllo':>7s} "
          f"{'vsDFX':>6s} {'1stPrin':>9s}")
    for r in rows:
        held = "*" if r["held_out"] else " "
        print(f"{r['in']:>4d}:{r['out']:<3d} {r['latency_ms']:9.1f} "
              f"{r['paper_latency_ms']:9.1f} {r['ttft_ms']:8.1f} "
              f"{r['paper_ttft_ms']:7.1f} {r['speed_tps']:7.1f} "
              f"{r['paper_speed_tps']:7.1f} {r['vs_allo']:7.2f} "
              f"{r['vs_dfx']:6.2f} {r['fp_latency_ms']:8.1f}{held}")
    held = [r for r in rows if r["held_out"]]
    worst = max(abs(r["latency_ratio"] - 1.0) for r in held)
    print(f"held-out rows (*fit excluded) worst latency error: "
          f"{worst*100:.1f}% (validation target <10%)")
    t = [r["ttft_ms"] for r in rows]
    print(f"TTFT scaling x{t[-1]/t[0]:.1f} over 8x input growth "
          f"(paper: x{TABLE4_OURS[(256, 256)][1]/TABLE4_OURS[(32, 32)][1]:.1f})")


if __name__ == "__main__":
    main()
