"""Paper-table benchmarks (one module per table/figure)."""
