"""Fig. 8 study: token-behavior FIFO sizing vs simulation, Normal vs
Conservative equalization, and LP-sized vs worst-case buffer area.

Uses the REAL GPT-2 block dataflow graph from our compiler:
  * validates that LP-sized FIFOs run deadlock-free in the discrete-event
    simulator at full throughput;
  * shows depth-2 FIFOs stall the pipeline (makespan regression);
  * compares Normal vs Conservative strategy: buffer bytes vs makespan
    (the paper's area/performance trade-off, §5.3.3);
  * compares LP total depth against the naive worst case (depth = T).
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import get_config
from repro.core.dse import evaluate_trial
from repro.core.fifo_sizing import size_fifos
from repro.core.platforms import U55C
from repro.core.trace import trace_block
from repro.runtime.simulator import simulate_dataflow


def run(tokens: int = 128) -> Dict[str, float]:
    cfg = get_config("gpt2")
    ops = trace_block(cfg, tokens=tokens)
    trial = evaluate_trial(ops, U55C, 32, 64, keep_artifacts=True)
    graph = trial.graph
    timings = {k.name: k.timing for k in graph.kernels()}

    out: Dict[str, float] = {}
    for strategy in ("normal", "conservative"):
        plan = size_fifos(graph, timings, strategy=strategy)
        sim = simulate_dataflow(graph, timings, plan=plan)
        assert sim.completed, f"{strategy} plan deadlocked!"
        out[f"{strategy}_bytes"] = plan.total_bytes
        out[f"{strategy}_depth"] = plan.total_depth
        out[f"{strategy}_makespan"] = sim.makespan
        # Sized >= observed peak occupancy on every edge (no back-pressure).
        viol = sum(1 for e, peak in sim.peak_occupancy.items()
                   if peak > plan.depths[e])
        out[f"{strategy}_violations"] = viol

    # Naive worst case: depth = full stream length T per edge.
    worst_bytes = sum(d["src_type"].num_tokens * d["src_type"].token_bytes
                      for _, _, _, d in graph.edges())
    out["worstcase_bytes"] = worst_bytes
    out["lp_area_saving"] = 1.0 - out["normal_bytes"] / worst_bytes

    # Depth-2 starvation: pipeline stalls (longer makespan), may deadlock.
    tiny = {(u, v, k): 2 for u, v, k, _ in graph.edges()}
    sim2 = simulate_dataflow(graph, timings, depths=tiny)
    out["depth2_completed"] = float(sim2.completed)
    out["depth2_makespan"] = sim2.makespan if sim2.completed else float("inf")
    return out


def main() -> None:
    r = run()
    print("# Fig. 8 — FIFO sizing (GPT-2 block dataflow graph)")
    print(f"normal:       depth={r['normal_depth']:5.0f} "
          f"bytes={r['normal_bytes']/2**20:6.2f}MB "
          f"makespan={r['normal_makespan']:9.0f}cyc "
          f"violations={r['normal_violations']:.0f}")
    print(f"conservative: depth={r['conservative_depth']:5.0f} "
          f"bytes={r['conservative_bytes']/2**20:6.2f}MB "
          f"makespan={r['conservative_makespan']:9.0f}cyc "
          f"violations={r['conservative_violations']:.0f}")
    print(f"worst-case bytes={r['worstcase_bytes']/2**20:.2f}MB -> LP saves "
          f"{r['lp_area_saving']*100:.1f}%")
    print(f"depth-2 FIFOs: completed={bool(r['depth2_completed'])} "
          f"makespan={r['depth2_makespan']:.0f}cyc "
          f"(vs {r['normal_makespan']:.0f} LP-sized)")


if __name__ == "__main__":
    main()
