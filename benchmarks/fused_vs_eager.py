"""Eager vs StreamPlan-fused execution benchmark -> BENCH_fused.json.

Measures the three model entry points under both execution paths:

  * ``forward_train`` — streamed-CE loss latency (tokens/s),
  * ``prefill``       — prompt ingestion latency,
  * decode            — engine tokens/s through the block-decode fast path
    (``decode_block`` ticks per jitted dispatch, donated slot cache).

Run on CPU the Pallas kernels execute in *interpret mode* (the kernel body
runs in Python per grid step), so fused numbers here validate the dispatch
plumbing and measure the perf *trajectory*, not the TPU speedup — on TPU
the same plan dispatches compiled MXU kernels.  The JSON records backend
and interpret mode so downstream dashboards can bucket the numbers.

    PYTHONPATH=src python benchmarks/fused_vs_eager.py [--quick] \
        [--out BENCH_fused.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.common import interpret_default
from repro.models import forward_train, init_params, prefill, resolve_plan
from repro.serving import ServingEngine

ARCHS = ("gpt2", "llama3-8b")        # layernorm/GELU-MLP and RMSNorm/SwiGLU-GQA


def _timed(fn: Callable[[], Any], iters: int) -> float:
    """Median wall-clock seconds over ``iters`` runs (post-warmup)."""
    jax.block_until_ready(fn())                  # compile + warm caches
    jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_config(arch: str, *, quick: bool) -> Dict[str, Any]:
    batch, seq = (2, 64) if quick else (2, 128)
    iters = 3 if quick else 7
    new_tokens = 16 if quick else 32
    decode_block = 8
    max_len = seq + new_tokens + decode_block

    base = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              base.vocab_size)
    train_batch = {"tokens": toks, "labels": toks}
    prompts = [np.asarray(toks[i]) for i in range(batch)]

    result: Dict[str, Any] = {
        "arch": base.name, "batch": batch, "seq": seq,
        "new_tokens": new_tokens, "decode_block": decode_block,
    }
    plan = resolve_plan(dataclasses.replace(base, use_fused_kernels=True),
                        batch * seq)
    result["plan"] = plan.summary()

    losses = {}
    for mode in ("eager", "fused"):
        cfg = dataclasses.replace(base, use_fused_kernels=(mode == "fused"))
        train_fn = jax.jit(lambda p, b: forward_train(p, cfg, b))
        prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b))

        train_s = _timed(lambda: train_fn(params, train_batch), iters)
        prefill_s = _timed(lambda: prefill_fn(params, train_batch)[0], iters)
        losses[mode] = float(train_fn(params, train_batch))

        engine = ServingEngine(cfg, params, batch_slots=batch,
                               max_len=max_len, decode_block=decode_block)
        engine.generate(prompts, max_new_tokens=2)   # compile prefill+decode
        t0 = time.perf_counter()
        reqs = engine.generate(prompts, max_new_tokens=new_tokens)
        decode_s = time.perf_counter() - t0
        generated = sum(len(r.out_tokens) for r in reqs)
        result[mode] = {
            "train_s": train_s,
            "train_tokens_per_s": batch * seq / train_s,
            "prefill_s": prefill_s,
            "prefill_tokens_per_s": batch * seq / prefill_s,
            "decode_s": decode_s,
            "decode_tokens_per_s": generated / decode_s,
            "ttft_s": float(np.mean([r.ttft_s for r in reqs])),
            "decode_dispatches": engine.metrics["dispatches"],
        }
    result["loss_abs_diff"] = abs(losses["eager"] - losses["fused"])
    result["fused_over_eager_train"] = (result["fused"]["train_s"]
                                        / result["eager"]["train_s"])
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: smaller shapes, fewer iterations")
    ap.add_argument("--out", default="BENCH_fused.json")
    ap.add_argument("--archs", default=",".join(ARCHS))
    args = ap.parse_args(argv)

    report: Dict[str, Any] = {
        "backend": jax.default_backend(),
        "pallas_interpret": interpret_default(),
        "quick": args.quick,
        "configs": [],
    }
    for arch in args.archs.split(","):
        t0 = time.perf_counter()
        r = bench_config(arch, quick=args.quick)
        r["bench_seconds"] = time.perf_counter() - t0
        report["configs"].append(r)
        e, f = r["eager"], r["fused"]
        print(f"{r['arch']}: train {e['train_s']*1e3:.1f}ms eager / "
              f"{f['train_s']*1e3:.1f}ms fused | decode "
              f"{e['decode_tokens_per_s']:.1f} vs "
              f"{f['decode_tokens_per_s']:.1f} tok/s | "
              f"loss diff {r['loss_abs_diff']:.2e}", flush=True)

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
