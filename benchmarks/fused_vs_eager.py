"""Eager vs StreamPlan-fused execution benchmark -> BENCH_fused.json.

Measures the three model entry points under both execution paths:

  * ``forward_train`` — streamed-CE loss latency (tokens/s),
  * ``prefill``       — prompt ingestion latency,
  * decode            — engine tokens/s through the continuous-batching
    block-decode fast path, CONTIGUOUS vs PAGED KV cache (tokens/s, peak
    cache bytes-in-use vs reserved, dispatch count).  The paged run is the
    engine default (page-table indirection + plan-selected Pallas paged
    decode attention under ``fused``); the contiguous run keeps the PR-1
    slots x max_len cache on the same scheduler for a like-for-like A/B.
  * prefill burst     — a mixed-length burst (>= 4 distinct prompt
    lengths) through a FRESH engine, CHUNKED prefill (one compiled
    program for the whole mix) vs the per-length-compile baseline:
    aggregate TTFT and the prefill compile count (the engine's
    trace-time probe).  The compile storm is the cost being measured, so
    no warmup run precedes the burst.
  * shared prefix     — the prefix-cache subsystem (DESIGN.md §10): a
    second request reusing a long cached prompt prefix vs the cold run
    on the same (pre-compiled) engine — TTFT, prefill chunk count,
    prefix hit rate, and the KV bytes NOT recomputed/restored; plus the
    bootstrap mode's decode-path first token for a fully cached prompt.
  * speculative       — self-speculative decoding (DESIGN.md §11):
    draft-then-verify vs the plain decode scan on REPETITIVE traffic
    (periodic prompts — the n-gram/prefix draft sources' home turf):
    accept rate, sequential model evaluations per generated token
    (plain = 1 scan tick per token; speculative = 1 verify dispatch per
    1..k+1 tokens), compiled verify-program count (the <=3-rung W
    ladder), tokens/s, and a greedy-token equality check.  The
    evaluations-per-token ratio is backend-independent; the tokens/s
    delta on CPU carries the interpret-mode caveat below.
  * sharded decode    — the mesh-aware StreamPlan (DESIGN.md §9): the
    fused engine on a (2, 4) ('data', 'model') mesh vs single-device,
    tokens/s plus KV bytes PER SHARD (the pools split over kv_heads) and
    a greedy-token equality check.  Needs >= 8 (forced) devices — run
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    ``sharded`` job does); skipped gracefully otherwise.
  * quantized         — quantized serving (DESIGN.md §14): the same
    engine under ``quant=kv_int8 / kv_fp8 / w8_kv8`` vs ``none`` — KV
    bytes per token, pages per slot, effective KV itemsize, plus the
    accuracy gate's max-logit-error and greedy-vs-f32 equality per mode.
  * latency distribution — the telemetry subsystem (DESIGN.md §17): a
    mixed chunked+speculative burst with the event recorder ON vs OFF —
    TTFT/TPOT/queue-wait p50/p90/p99 from the windowed metric snapshot,
    the recorded event count, the wall-clock overhead ratio (telemetry
    must stay under a few percent) and a greedy-token equality check
    (telemetry is a pure observer).

``interpret_mode`` is reported ONCE at the report's top level (every
fused number in the file shares the same backend).

Run on CPU the Pallas kernels execute in *interpret mode* (the kernel body
runs in Python per grid step), so fused numbers here validate the dispatch
plumbing and measure the perf *trajectory*, not the TPU speedup — on TPU
the same plan dispatches compiled MXU kernels.  Every fused result embeds
``interpret_mode`` so a fused-slower-than-eager row on CPU is read as the
interpreter tax, not a kernel regression; the decode section's cache-bytes
numbers are backend-independent.

    PYTHONPATH=src python benchmarks/fused_vs_eager.py [--quick] \
        [--out BENCH_fused.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.common import interpret_default
from repro.models import (forward_train, init_params, prefill, resolve_plan,
                          supports_chunked_prefill, supports_speculative)
from repro.serving import ServingEngine
from repro.serving.accuracy import run_accuracy, supports_quantized_serving

ARCHS = ("gpt2", "llama3-8b")        # layernorm/GELU-MLP and RMSNorm/SwiGLU-GQA


def _timed(fn: Callable[[], Any], iters: int) -> float:
    """Median wall-clock seconds over ``iters`` runs (post-warmup)."""
    jax.block_until_ready(fn())                  # compile + warm caches
    jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_sharded_decode(base, *, batch: int, max_len: int,
                         decode_block: int, new_tokens: int) -> Dict[str, Any]:
    """Sharded vs single-device fused decode through the serving engine.

    Uses a head layout whose kv_heads divide the 4-way model axis (the
    reduced configs' GQA ratio often doesn't) so the KV pools actually
    split; reports per-shard KV bytes — the number that scales capacity.
    """
    if len(jax.devices()) < 8:
        return {"skipped": "needs 8 (forced) host devices — run under "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8"}
    from repro.launch.mesh import make_mesh
    cfg = dataclasses.replace(base, use_fused_kernels=True, num_heads=8,
                              num_kv_heads=4, head_dim=8)
    params = init_params(jax.random.PRNGKey(2), cfg)
    nprng = np.random.default_rng(5)
    prompts = [nprng.integers(1, cfg.vocab_size, n, dtype=np.int32)
               for n in (max_len // 2, max_len // 4)][:batch]
    out: Dict[str, Any] = {}
    tokens = {}
    for name, mesh in (("single", None),
                       ("sharded", make_mesh((2, 4), ("data", "model")))):
        eng = ServingEngine(cfg, params, batch_slots=batch, max_len=max_len,
                            decode_block=decode_block, mesh=mesh,
                            prefix_cache=False)      # measure cold prefill
        eng.generate(prompts, max_new_tokens=2)      # compile
        t0 = time.perf_counter()
        reqs = eng.generate(prompts, max_new_tokens=new_tokens)
        wall = time.perf_counter() - t0
        generated = sum(len(r.out_tokens) for r in reqs)
        tokens[name] = [r.out_tokens for r in reqs]
        out[name] = {
            "decode_s": wall,
            "decode_tokens_per_s": generated / wall,
            "kv_shards": eng.metrics["kv_shards"],
            "kv_bytes_peak": eng.metrics["kv_bytes_peak"],
            "kv_bytes_peak_per_shard": eng.kv.peak_bytes_per_shard,
        }
        if mesh is not None:
            out[name]["plan_sharding"] = eng.plan.summary()["sharding"]
    out["tokens_equal"] = tokens["single"] == tokens["sharded"]
    return out


def bench_quantized(base, params, *, max_len: int, decode_block: int,
                    new_tokens: int) -> Dict[str, Any]:
    """Quantized serving (DESIGN.md §14): kv_int8/kv_fp8/w8_kv8 vs none.

    Same engine, same prompts, one run per mode: KV bytes per token and
    pages per slot (the capacity numbers halving the page itemsize
    buys), the effective KV itemsize (codes + f32 scale rows), and —
    from the teacher-forced accuracy harness — max logit error vs f32
    and greedy-token equality per mode.
    """
    if not supports_quantized_serving(base):
        return {"skipped": f"{base.name}: no paged attention KV "
                           "(quantized pages ride on it)"}
    modes = ("kv_int8", "kv_fp8", "w8_kv8")
    acc = run_accuracy(base, modes=modes, steps=6)
    nprng = np.random.default_rng(33)
    prompts = [nprng.integers(1, base.vocab_size, n, dtype=np.int32)
               for n in (max_len // 2, max_len // 4)]
    out: Dict[str, Any] = {}
    for quant in ("none",) + modes:
        eng = ServingEngine(base, params, batch_slots=len(prompts),
                            max_len=max_len, decode_block=decode_block,
                            quant=quant, prefix_cache=False)
        eng.generate([p.copy() for p in prompts],
                     max_new_tokens=2)               # absorb compiles
        t0 = time.perf_counter()
        reqs = eng.generate([p.copy() for p in prompts],
                            max_new_tokens=new_tokens)
        wall = time.perf_counter() - t0
        generated = sum(len(r.out_tokens) for r in reqs)
        cached = sum(len(p) for p in prompts) + generated
        peak = eng.metrics["kv_bytes_peak"]
        row: Dict[str, Any] = {
            "decode_tokens_per_s": generated / wall,
            "kv_bytes_peak": int(peak),
            "kv_bytes_per_token": peak / cached,
            "pages_per_slot": (peak / eng.kv.page_bytes) / len(prompts),
            "kv_itemsize_effective":
                eng.metrics["kv_itemsize_effective"],
        }
        if quant != "none":
            row["max_logit_err"] = acc[quant]["max_logit_err"]
            row["tokens_equal_f32"] = bool(acc[quant]["tokens_equal"])
        out[quant] = row
    out["kv_int8_over_none_bytes"] = (
        out["kv_int8"]["kv_bytes_peak"]
        / max(out["none"]["kv_bytes_peak"], 1))
    return out


def bench_prefix_serving(base, params, *, max_len: int,
                         decode_block: int) -> Dict[str, Any]:
    """Hot-prefix vs cold serving TTFT through the prefix cache.

    One engine serves three waves: a token-distinct warmup (absorbs the
    chunk/decode compiles and shares nothing), a COLD request, then a HOT
    request reusing the cold one's long prefix — so the TTFT delta is
    pure prefill work, not compile noise.  KV bytes saved = pages claimed
    instead of recomputed-and-restored, times the page byte size.  A
    second engine measures ``prefix_bootstrap`` on a fully cached prompt
    (first token through the decode path alone).
    """
    if not supports_chunked_prefill(base):
        return {"skipped": f"{base.name}: no chunked prefill "
                           "(prefix cache rides on it)"}
    # Fine stream granules so the shared prefix spans many chunks (the
    # eager default chunk of 4 pages x 16 would swallow it whole), and a
    # page-aligned prompt so the bootstrap leg gets a full hit.
    ps, chunk, pairs = 8, 16, 3
    nprng = np.random.default_rng(21)
    plen = (3 * max_len // 4) // ps * ps
    prefix_len = plen - ps

    def mk(prefix, tail_seed):
        tail = np.random.default_rng(tail_seed).integers(
            1, base.vocab_size, ps, dtype=np.int32)
        return np.concatenate([prefix, tail]).astype(np.int32)

    warmup = nprng.integers(1, base.vocab_size, plen, dtype=np.int32)
    eng = ServingEngine(base, params, batch_slots=2, max_len=max_len,
                        decode_block=decode_block, page_size=ps,
                        prefill_chunk=chunk)
    eng.generate([warmup], max_new_tokens=2)       # absorb the compiles
    ttft_cold, ttft_hot, chunks = [], [], []
    for i in range(pairs):                         # fresh prefix per pair
        prefix = nprng.integers(1, base.vocab_size, prefix_len,
                                dtype=np.int32)
        c0 = eng.metrics["prefill_chunks"]
        cold = eng.generate([mk(prefix, 2 * i)], max_new_tokens=4)[0]
        c1 = eng.metrics["prefill_chunks"]
        hot = eng.generate([mk(prefix, 2 * i + 1)], max_new_tokens=4)[0]
        c2 = eng.metrics["prefill_chunks"]
        ttft_cold.append(cold.ttft_s)
        ttft_hot.append(hot.ttft_s)
        chunks.append((c1 - c0, c2 - c1))
    tc, th = float(np.median(ttft_cold)), float(np.median(ttft_hot))
    out: Dict[str, Any] = {
        "prompt_len": plen,
        "shared_prefix_len": prefix_len,
        "ttft_cold_s": tc,
        "ttft_hot_s": th,
        "hot_over_cold_ttft": th / max(tc, 1e-9),
        "prefill_chunks_cold": chunks[-1][0],
        "prefill_chunks_hot": chunks[-1][1],
        "prefix_hit_rate": eng.metrics["prefix_hit_rate"],
        "prefix_hit_pages": int(eng.metrics["prefix_hit_pages"]),
        "kv_bytes_saved": int(eng.metrics["prefix_hit_pages"]
                              * eng.kv.page_bytes),
        "kv_bytes_cached": int(eng.metrics["kv_bytes_cached"]),
        "kv_itemsize_effective": eng.metrics["kv_itemsize_effective"],
    }
    boot = ServingEngine(base, params, batch_slots=2, max_len=max_len,
                         decode_block=decode_block, page_size=ps,
                         prefill_chunk=chunk, prefix_bootstrap=True)
    boot.generate([warmup], max_new_tokens=2)        # compile
    cached_p = mk(warmup[:prefix_len], 99)
    boot.generate([cached_p], max_new_tokens=4)      # cache the prompt
    tts = []
    for _ in range(pairs):                           # fully cached replays
        tts.append(boot.generate([cached_p],
                                 max_new_tokens=4)[0].ttft_s)
    out["ttft_bootstrap_s"] = float(np.median(tts))
    out["bootstraps"] = int(boot.metrics["prefix_bootstraps"])
    out["cow_copies"] = int(boot.metrics["cow_copies"])
    return out


def bench_speculative(base, params, *, max_len: int, decode_block: int,
                      new_tokens: int) -> Dict[str, Any]:
    """Speculative vs plain decode on repetitive ("agentic") traffic.

    The comparison that matters is SEQUENTIAL MODEL EVALUATIONS per
    generated token — the quantity a real accelerator's decode latency
    scales with.  Plain decode pays one scan tick per token PER SLOT
    (``scan_ticks / generated``; batching amortizes a tick over the
    slots, so the value sits below 1 with several slots active);
    speculative decode pays one verify dispatch per 1..draft_len+1
    tokens per slot (``verify_dispatches / spec_tokens``).  Both count
    sequential steps over tokens delivered across the whole batch, so
    the ratio is like-for-like.  Both engines run the same prompts and
    the greedy tokens must be identical — speculation is a pure perf
    knob.
    """
    if not supports_speculative(base):
        return {"skipped": f"{base.name}: no speculative decoding "
                           "(recurrent state cannot roll back)"}
    cfg = dataclasses.replace(base, use_fused_kernels=True)
    # Periodic prompts: random-weight reduced models collapse onto
    # repeating cycles on these, so n-gram prompt-lookup drafting fires
    # the way it does on real looping/agentic traffic.
    periods = ((1, 2, 3, 4), (7, 8, 9), (5, 6))
    prompts = [np.array((p * max_len)[:max_len // 3], np.int32)
               for p in periods]
    out: Dict[str, Any] = {}
    tokens = {}
    for name in ("plain", "speculative"):
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=max_len,
                            decode_block=decode_block,
                            speculative=(name == "speculative"),
                            draft_len=4)
        eng.generate([p.copy() for p in prompts],
                     max_new_tokens=2)               # absorb compiles
        m0 = dict(eng.metrics)
        t0 = time.perf_counter()
        reqs = eng.generate([p.copy() for p in prompts],
                            max_new_tokens=new_tokens)
        wall = time.perf_counter() - t0
        generated = sum(len(r.out_tokens) for r in reqs)
        tokens[name] = [r.out_tokens for r in reqs]
        row: Dict[str, Any] = {
            "decode_s": wall,
            "decode_tokens_per_s": generated / wall,
            "generated": generated,
        }
        if name == "speculative":
            spec = eng.metrics["spec_tokens"] - m0["spec_tokens"]
            disp = (eng.metrics["verify_dispatches"]
                    - m0["verify_dispatches"])
            drafted = eng.metrics["draft_tokens"] - m0["draft_tokens"]
            accepted = (eng.metrics["accepted_tokens"]
                        - m0["accepted_tokens"])
            row.update({
                "evals_per_token": disp / max(spec, 1),
                "accept_rate": accepted / max(drafted, 1),
                "draft_tokens": drafted,
                "accepted_tokens": accepted,
                "rollback_pages": int(eng.metrics["rollback_pages"]
                                      - m0["rollback_pages"]),
                # Programs built across BOTH runs: the ladder cap, not a
                # per-run delta.
                "verify_compiles": int(eng.metrics["verify_traces"]),
            })
        else:
            ticks = eng.metrics["scan_ticks"] - m0["scan_ticks"]
            gen = eng.metrics["generated"] - m0["generated"]
            row["evals_per_token"] = ticks / max(gen, 1)
        out[name] = row
    out["tokens_equal"] = tokens["plain"] == tokens["speculative"]
    out["plain_over_speculative_evals"] = (
        out["plain"]["evals_per_token"]
        / max(out["speculative"]["evals_per_token"], 1e-9))
    if interpret_default():
        out["note"] = ("CPU interpret mode: tokens/s measures dispatch "
                       "plumbing; the evals-per-token ratio is the "
                       "backend-independent speculative win.")
    return out


def bench_autotune(base, params, *, max_len: int, decode_block: int,
                   new_tokens: int) -> Dict[str, Any]:
    """Autotuned vs analytic serving (DESIGN.md §16).

    Three engines over identical prompts: the analytic baseline, a COLD
    autotuned start (tunes every fused stage, persists the table), and a
    WARM start against the same table — which must perform zero
    measurement dispatches and resolve a bit-identical plan.  Records
    tuned-vs-analytic decode tokens/s and TTFT, the candidate/pruned/
    measured counters, and the plan provenance.  Deviceless runs score
    candidates with the analytic surrogate, so the deltas are noise —
    the section's value there is exercising the whole tune/persist/
    reload pipeline on every benchmark run.
    """
    import tempfile

    from repro.core.stream_plan import plan_for

    nprng = np.random.default_rng(47)
    prompts = [nprng.integers(1, base.vocab_size, n, dtype=np.int32)
               for n in (max_len // 2, max_len // 4)]

    def serve(**engine_kw) -> Dict[str, Any]:
        eng = ServingEngine(base, params, batch_slots=len(prompts),
                            max_len=max_len, decode_block=decode_block,
                            prefix_cache=False, **engine_kw)
        eng.generate([p.copy() for p in prompts],
                     max_new_tokens=2)               # absorb compiles
        t0 = time.perf_counter()
        reqs = eng.generate([p.copy() for p in prompts],
                            max_new_tokens=new_tokens)
        wall = time.perf_counter() - t0
        generated = sum(len(r.out_tokens) for r in reqs)
        return {
            "engine": eng,
            "tokens": [r.out_tokens for r in reqs],
            "decode_tokens_per_s": generated / wall,
            "ttft_s": float(np.nanmean([r.ttft_s for r in reqs])),
        }

    with tempfile.TemporaryDirectory(prefix="repro_tune_") as d:
        plan_for.cache_clear()
        analytic = serve()
        plan_for.cache_clear()
        cold = serve(autotune=d)
        plan_for.cache_clear()
        warm = serve(autotune=d)
        e_cold, e_warm = cold["engine"], warm["engine"]
        out: Dict[str, Any] = {
            "analytic": {k: v for k, v in analytic.items()
                         if k in ("decode_tokens_per_s", "ttft_s")},
            "tuned_cold": {
                "decode_tokens_per_s": cold["decode_tokens_per_s"],
                "ttft_s": cold["ttft_s"],
                "candidates": e_cold.tuner.stats.candidates,
                "pruned_by_lint": e_cold.tuner.stats.pruned,
                "measured": e_cold.tuner.stats.measured,
                "stages_tuned": e_cold.tuner.stats.stages,
                "table_entries": e_cold.metrics["tune_entries"],
            },
            "tuned_warm": {
                "decode_tokens_per_s": warm["decode_tokens_per_s"],
                "ttft_s": warm["ttft_s"],
                "measured": e_warm.tuner.stats.measured,
                "table_hits": e_warm.metrics["tune_hits"],
            },
            "plan_source": e_warm.metrics["plan_source"],
            "plans_identical": e_cold.plan == e_warm.plan,
            "tokens_equal_analytic":
                cold["tokens"] == analytic["tokens"] == warm["tokens"],
            "tuned_over_analytic_decode":
                warm["decode_tokens_per_s"]
                / max(analytic["decode_tokens_per_s"], 1e-9),
        }
    plan_for.cache_clear()       # drop tuned plans from the shared cache
    return out


def bench_latency_distribution(base, params, *, max_len: int,
                               decode_block: int,
                               new_tokens: int) -> Dict[str, Any]:
    """Telemetry on vs off on a mixed chunked+speculative burst
    (DESIGN.md §17).

    Two engines over the same mixed-length repetitive burst: one with
    the observability subsystem recording the full event stream, one
    with the no-op recorder.  Both are warmed first so the walls
    compare steady-state dispatch loops, not compiles.  Records the
    TTFT/TPOT/queue-wait percentile fields from the windowed snapshot
    (``snapshot("last_generate")`` — the measured burst only), the
    event count, the median-of-3 wall-clock overhead ratio, and a
    greedy-token equality check: telemetry must be a pure observer.
    """
    if not (supports_chunked_prefill(base) and supports_speculative(base)):
        return {"skipped": f"{base.name}: needs chunked prefill and "
                           "speculative decoding"}
    cfg = dataclasses.replace(base, use_fused_kernels=True)
    periods = ((1, 2, 3, 4), (7, 8, 9), (5, 6), (2, 9))
    prompts = [np.array((p * max_len)[:n], np.int32)
               for p, n in zip(periods, (max_len // 3, max_len // 6,
                                         max_len // 2, max_len // 4))]

    def serve(telemetry: bool) -> Dict[str, Any]:
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=max_len,
                            decode_block=decode_block, chunked=True,
                            prefill_chunk=max(8, max_len // 8),
                            speculative=True, draft_len=4,
                            telemetry=telemetry)
        eng.generate([p.copy() for p in prompts],
                     max_new_tokens=2)               # absorb compiles
        walls = []
        for _ in range(3):
            t0 = time.perf_counter()
            reqs = eng.generate([p.copy() for p in prompts],
                                max_new_tokens=new_tokens)
            walls.append(time.perf_counter() - t0)
        return {"engine": eng, "wall_s": float(np.median(walls)),
                "tokens": [r.out_tokens for r in reqs]}

    on, off = serve(True), serve(False)
    eng = on["engine"]
    snap = eng.snapshot("last_generate")             # the last burst only
    out: Dict[str, Any] = {
        "wall_on_s": on["wall_s"],
        "wall_off_s": off["wall_s"],
        "overhead_ratio": on["wall_s"] / max(off["wall_s"], 1e-9),
        "tokens_equal": on["tokens"] == off["tokens"],
        "events": len(eng.obs.events),
    }
    for h in ("ttft_s", "tpot_s", "queue_wait_s"):
        out[h] = {k: snap[f"{h}_{k}"]
                  for k in ("count", "mean", "p50", "p90", "p99")}
    return out


def bench_config(arch: str, *, quick: bool) -> Dict[str, Any]:
    batch, seq = (2, 64) if quick else (2, 128)
    iters = 3 if quick else 7
    new_tokens = 16 if quick else 32
    decode_block = 8
    max_len = seq + new_tokens + decode_block

    base = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, base)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              base.vocab_size)
    train_batch = {"tokens": toks, "labels": toks}
    # Heterogeneous prompt lengths: the continuous engine places each at
    # its own offset, and the paged cache only allocates the pages each
    # one actually needs (the contiguous cache reserves max_len for both).
    prompts = [np.asarray(toks[i][:seq if i % 2 == 0 else seq // 2])
               for i in range(batch)]

    result: Dict[str, Any] = {
        "arch": base.name, "batch": batch, "seq": seq,
        "new_tokens": new_tokens, "decode_block": decode_block,
    }
    fused_cfg = dataclasses.replace(base, use_fused_kernels=True)
    plan = resolve_plan(fused_cfg, batch * seq)
    # Static verification (DESIGN.md §15): BENCH_fused.json records
    # whether the plan it benchmarked passed the stream verifier.
    from repro.analysis import errors as _diag_errors, verify_plan
    diags = verify_plan(plan, fused_cfg)
    plan = plan.with_verification(not _diag_errors(diags),
                                  tuple(str(d) for d in diags))
    result["plan"] = plan.summary()

    losses = {}
    for mode in ("eager", "fused"):
        cfg = dataclasses.replace(base, use_fused_kernels=(mode == "fused"))
        train_fn = jax.jit(lambda p, b: forward_train(p, cfg, b))
        prefill_fn = jax.jit(lambda p, b: prefill(p, cfg, b))

        train_s = _timed(lambda: train_fn(params, train_batch), iters)
        prefill_s = _timed(lambda: prefill_fn(params, train_batch)[0], iters)
        losses[mode] = float(train_fn(params, train_batch))

        decode: Dict[str, Any] = {}
        for paged in (False, True):
            # prefix_cache off: the warmup generate would otherwise cache
            # these prompts and make the measured run prefill-hot — the
            # prefix win is measured in its own section below.
            engine = ServingEngine(cfg, params, batch_slots=batch,
                                   max_len=max_len,
                                   decode_block=decode_block, paged=paged,
                                   prefix_cache=False)
            engine.generate(prompts, max_new_tokens=2)  # compile
            d0 = engine.metrics["dispatches"]
            g0 = engine.metrics["generated"]
            t0 = time.perf_counter()
            reqs = engine.generate(prompts, max_new_tokens=new_tokens)
            decode_s = time.perf_counter() - t0
            generated = sum(len(r.out_tokens) for r in reqs)
            decode["paged" if paged else "contiguous"] = {
                "decode_s": decode_s,
                "decode_tokens_per_s": generated / decode_s,
                "ttft_s": float(np.mean([r.ttft_s for r in reqs])),
                "dispatches": engine.metrics["dispatches"] - d0,
                "generated": engine.metrics["generated"] - g0,
                "kv_bytes_reserved": engine.metrics["kv_bytes_reserved"],
                "kv_bytes_peak": engine.metrics["kv_bytes_peak"],
                "kv_itemsize_effective":
                    engine.metrics["kv_itemsize_effective"],
                "page_size": engine.metrics["page_size"],
            }
        decode["paged_over_contiguous_bytes"] = (
            decode["paged"]["kv_bytes_peak"]
            / max(decode["contiguous"]["kv_bytes_peak"], 1))

        # Mixed-length prefill burst: chunked (one program) vs per-length
        # (one program per distinct length).  Fresh engines, no warmup —
        # compile latency IS the number under test.  Archs outside the
        # chunked gate (SSM/RWKV/mrope) skip the section rather than
        # crash the report.
        burst_lens = sorted({max(4, seq // 4), seq // 2,
                             max(8, 3 * seq // 4), seq})
        nprng = np.random.default_rng(12)
        burst_prompts = [nprng.integers(1, base.vocab_size, n,
                                        dtype=np.int32)
                         for n in burst_lens]
        burst: Dict[str, Any] = {"lengths": burst_lens}
        modes = ((("chunked", True),) if supports_chunked_prefill(base)
                 else ()) + (("per_length", False),)
        for bname, chunk_mode in modes:
            eng = ServingEngine(cfg, params, batch_slots=batch,
                                max_len=max_len,
                                decode_block=decode_block,
                                chunked=chunk_mode)
            t0 = time.perf_counter()
            breqs = eng.generate(burst_prompts, max_new_tokens=4)
            wall = time.perf_counter() - t0
            ttfts = [r.ttft_s for r in breqs]
            burst[bname] = {
                "wall_s": wall,
                "ttft_mean_s": float(np.nanmean(ttfts)),
                "ttft_max_s": float(np.nanmax(ttfts)),
                "prefill_compiles": int(eng.metrics["prefill_traces"]),
                "prefill_chunks": int(eng.metrics["prefill_chunks"]),
                "prefill_chunk": int(eng.metrics["prefill_chunk"]),
            }
        if "chunked" in burst:
            burst["chunked_over_per_length_ttft"] = (
                burst["chunked"]["ttft_mean_s"]
                / max(burst["per_length"]["ttft_mean_s"], 1e-9))

        result[mode] = {
            "prefill_burst": burst,
            "train_s": train_s,
            "train_tokens_per_s": batch * seq / train_s,
            "prefill_s": prefill_s,
            "prefill_tokens_per_s": batch * seq / prefill_s,
            # Headline decode numbers come from the engine default (paged).
            "decode_s": decode["paged"]["decode_s"],
            "decode_tokens_per_s": decode["paged"]["decode_tokens_per_s"],
            "ttft_s": decode["paged"]["ttft_s"],
            "decode_dispatches": decode["paged"]["dispatches"],
            "decode": decode,
        }
        if mode == "fused":
            if interpret_default():
                result[mode]["note"] = (
                    "Pallas kernels ran in interpret mode (no TPU): "
                    "fused-slower-than-eager here is interpreter tax, "
                    "not a kernel regression.")
    result["loss_abs_diff"] = abs(losses["eager"] - losses["fused"])
    result["fused_over_eager_train"] = (result["fused"]["train_s"]
                                        / result["eager"]["train_s"])
    result["prefix_serving"] = bench_prefix_serving(
        base, params, max_len=max_len, decode_block=decode_block)
    result["speculative"] = bench_speculative(
        base, params, max_len=max_len, decode_block=decode_block,
        new_tokens=new_tokens)
    result["sharded_decode"] = bench_sharded_decode(
        base, batch=batch, max_len=max_len, decode_block=decode_block,
        new_tokens=new_tokens)
    result["quantized"] = bench_quantized(
        base, params, max_len=max_len, decode_block=decode_block,
        new_tokens=new_tokens)
    result["autotune"] = bench_autotune(
        fused_cfg, params, max_len=max_len, decode_block=decode_block,
        new_tokens=new_tokens)
    result["latency_distribution"] = bench_latency_distribution(
        base, params, max_len=max_len, decode_block=decode_block,
        new_tokens=new_tokens)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: smaller shapes, fewer iterations")
    ap.add_argument("--out", default="BENCH_fused.json")
    ap.add_argument("--archs", default=",".join(ARCHS))
    args = ap.parse_args(argv)

    report: Dict[str, Any] = {
        "backend": jax.default_backend(),
        # ONE top-level flag: every fused number below shares the same
        # backend, so per-section copies only invited drift.
        "interpret_mode": interpret_default(),
        "quick": args.quick,
        "configs": [],
    }
    for arch in args.archs.split(","):
        t0 = time.perf_counter()
        r = bench_config(arch, quick=args.quick)
        r["bench_seconds"] = time.perf_counter() - t0
        report["configs"].append(r)
        e, f = r["eager"], r["fused"]
        dc = e["decode"]
        pb = e["prefill_burst"]
        if "chunked" in pb:
            burst_note = (
                f"burst ttft {pb['chunked']['ttft_mean_s']*1e3:.0f}ms "
                f"({pb['chunked']['prefill_compiles']} compile) vs "
                f"{pb['per_length']['ttft_mean_s']*1e3:.0f}ms "
                f"({pb['per_length']['prefill_compiles']} compiles)")
        else:
            burst_note = (
                f"burst ttft {pb['per_length']['ttft_mean_s']*1e3:.0f}ms "
                f"({pb['per_length']['prefill_compiles']} compiles, "
                "no chunked support)")
        px = r["prefix_serving"]
        if "skipped" in px:
            prefix_note = "prefix serving skipped"
        else:
            prefix_note = (
                f"prefix ttft {px['ttft_hot_s']*1e3:.0f}ms hot / "
                f"{px['ttft_cold_s']*1e3:.0f}ms cold "
                f"(hit rate {px['prefix_hit_rate']:.2f}, "
                f"{px['kv_bytes_saved']} B saved, "
                f"bootstrap {px['ttft_bootstrap_s']*1e3:.0f}ms)")
        sp = r["speculative"]
        if "skipped" in sp:
            spec_note = "speculative skipped"
        else:
            spec_note = (
                f"spec {sp['speculative']['evals_per_token']:.2f} vs "
                f"{sp['plain']['evals_per_token']:.2f} evals/tok "
                f"(x{sp['plain_over_speculative_evals']:.1f}, accept "
                f"{sp['speculative']['accept_rate']:.2f}, "
                f"{sp['speculative']['verify_compiles']} verify "
                f"compiles, tokens_equal={sp['tokens_equal']})")
        sd = r["sharded_decode"]
        if "skipped" in sd:
            shard_note = "sharded decode skipped (<8 devices)"
        else:
            shard_note = (
                f"sharded {sd['sharded']['decode_tokens_per_s']:.1f} tok/s "
                f"x{sd['sharded']['kv_shards']} shards "
                f"({sd['sharded']['kv_bytes_peak_per_shard']} B/shard, "
                f"tokens_equal={sd['tokens_equal']})")
        qz = r["quantized"]
        if "skipped" in qz:
            quant_note = "quantized skipped"
        else:
            q8 = qz["kv_int8"]
            quant_note = (
                f"kv_int8 {q8['kv_bytes_per_token']:.0f} B/tok "
                f"(x{qz['kv_int8_over_none_bytes']:.2f} bytes, itemsize "
                f"{q8['kv_itemsize_effective']:.2f}B, max|dlogit| "
                f"{q8['max_logit_err']:.3g}, "
                f"tokens_equal={q8['tokens_equal_f32']})")
        at = r["autotune"]
        tune_note = (
            f"autotune x{at['tuned_over_analytic_decode']:.2f} decode "
            f"({at['tuned_cold']['candidates']} cands, "
            f"{at['tuned_cold']['pruned_by_lint']} pruned, warm "
            f"measured={at['tuned_warm']['measured']}, "
            f"identical={at['plans_identical']})")
        ld = r["latency_distribution"]
        if "skipped" in ld:
            lat_note = "latency distribution skipped"
        else:
            lat_note = (
                f"telemetry overhead x{ld['overhead_ratio']:.3f} "
                f"({ld['events']} events, ttft p50/p90/p99 "
                f"{ld['ttft_s']['p50']*1e3:.0f}/"
                f"{ld['ttft_s']['p90']*1e3:.0f}/"
                f"{ld['ttft_s']['p99']*1e3:.0f}ms, "
                f"tokens_equal={ld['tokens_equal']})")
        print(f"{r['arch']}: train {e['train_s']*1e3:.1f}ms eager / "
              f"{f['train_s']*1e3:.1f}ms fused | decode "
              f"{e['decode_tokens_per_s']:.1f} vs "
              f"{f['decode_tokens_per_s']:.1f} tok/s | "
              f"kv peak {dc['paged']['kv_bytes_peak']} paged / "
              f"{dc['contiguous']['kv_bytes_peak']} contiguous bytes | "
              f"{burst_note} | {prefix_note} | {spec_note} | "
              f"{shard_note} | {quant_note} | {tune_note} | "
              f"{lat_note} | loss diff {r['loss_abs_diff']:.2e}",
              flush=True)

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
