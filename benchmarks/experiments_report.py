"""EXPERIMENTS.md §Dry-run/§Roofline section generator.

Reads results/dryrun/*.json and emits the markdown tables (baseline cells
plus any __perf_<mode> variants side by side).
"""

from __future__ import annotations

import glob
import json
import os
from collections import defaultdict
from typing import Dict, List

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load() -> Dict[str, Dict]:
    out = {}
    for f in sorted(glob.glob(f"{RESULTS}/*.json")):
        tag = os.path.basename(f)[:-5]
        r = json.load(open(f))
        if "error" not in r:
            out[tag] = r
    return out


def dryrun_table(recs: Dict[str, Dict]) -> str:
    rows = ["| arch | shape | mesh | compile (s) | args GiB/dev | "
            "temp GiB/dev | HLO GFLOP/dev | coll GiB/dev | #coll |",
            "|---|---|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if "__perf" in tag:
            continue
        m, c = r["memory"], r["collectives"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']:.1f} | {m['argument_bytes']/2**30:.2f} "
            f"| {m['temp_bytes']/2**30:.2f} "
            f"| {r['cost']['flops']/1e9:.0f} "
            f"| {c['total']/2**30:.2f} | {c['counts']} |")
    return "\n".join(rows)


def roofline_table(recs: Dict[str, Dict]) -> str:
    rows = ["| arch | shape | mesh | compute (s) | memory (s) | "
            "collective (s) | bound | MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if "__perf" in tag:
            continue
        rl = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {rl['bound']} "
            f"| {rl['useful_flops_ratio']:.3f} "
            f"| {rl['mfu_upper_bound']*100:.2f}% |")
    return "\n".join(rows)


def perf_table(recs: Dict[str, Dict]) -> str:
    """Baseline vs perf variants for the hillclimbed cells."""
    groups: Dict[str, List[str]] = defaultdict(list)
    for tag in recs:
        if "__perf" in tag:
            base = tag.split("__perf")[0]
            groups[base].append(tag)
    rows = ["| cell | variant | compute (s) | memory (s) | collective (s) "
            "| bound | temp GiB/dev | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for base in sorted(groups):
        seq = [base] + sorted(groups[base])
        for tag in seq:
            if tag not in recs:
                continue
            r = recs[tag]
            rl = r["roofline"]
            variant = ("baseline" if tag == base
                       else "perf:" + tag.split("__perf_")[1])
            cell = f"{r['arch']} x {r['shape']} ({r['mesh']})"
            rows.append(
                f"| {cell} | {variant} | {rl['compute_s']:.3f} "
                f"| {rl['memory_s']:.3f} | {rl['collective_s']:.3f} "
                f"| {rl['bound']} "
                f"| {r['memory']['temp_bytes']/2**30:.1f} "
                f"| {rl['mfu_upper_bound']*100:.2f}% |")
    return "\n".join(rows)


def main() -> None:
    recs = load()
    n_base = sum(1 for t in recs if "__perf" not in t)
    print(f"<!-- generated from {RESULTS}: {n_base} baseline cells, "
          f"{len(recs)-n_base} perf variants -->\n")
    print("### Dry-run records\n")
    print(dryrun_table(recs))
    print("\n### Roofline terms (baseline)\n")
    print(roofline_table(recs))
    print("\n### Perf variants\n")
    print(perf_table(recs))


if __name__ == "__main__":
    main()
